"""§Perf optimization variants must be semantics-preserving: every lever
(grouped/gather MoE dispatch, streamed CE, bf16 norm apply, grad accumulation)
is checked against its baseline."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model, lm_loss
from repro.train.train_step import TrainConfig, init_state, make_train_step

KEY = jax.random.PRNGKey(0)


def _moe_cfg(arch, **over):
    cfg = get_smoke_config(arch)
    return dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1e9, **over))


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b", "deepseek-v3-671b"])
def test_grouped_dispatch_matches_global_sort(arch):
    cfg_g = _moe_cfg(arch)
    cfg_s = _moe_cfg(arch, dispatch="global_sort")
    model = get_model(cfg_g)
    params = model.init(KEY, cfg_g, 64)
    batch = {"tokens": jax.random.randint(KEY, (2, 24), 0, cfg_g.vocab_size)}
    a = model.forward_train(params, batch, cfg_g)
    b = model.forward_train(params, batch, cfg_s)
    a = a[0] if isinstance(a, tuple) else a
    b = b[0] if isinstance(b, tuple) else b
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_moe_capacity_dropping_is_deterministic():
    """With a tight capacity, dropping favors earlier tokens per expert and
    is identical across dispatch impls."""
    cfg_g = dataclasses.replace(get_smoke_config("deepseek-v2-lite-16b"))
    cfg_s = dataclasses.replace(
        cfg_g, moe=dataclasses.replace(cfg_g.moe, dispatch="global_sort"))
    model = get_model(cfg_g)
    params = model.init(KEY, cfg_g, 64)
    batch = {"tokens": jax.random.randint(KEY, (2, 24), 0, cfg_g.vocab_size)}
    a = model.forward_train(params, batch, cfg_g)
    b = model.forward_train(params, batch, cfg_s)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v3-671b"])
def test_streamed_ce_matches_naive(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1e9))
    cfg_s = dataclasses.replace(cfg, loss_impl="streamed")
    model = get_model(cfg)
    params = model.init(KEY, cfg, 64)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size),
             "loss_mask": jnp.ones((2, 32), jnp.int32).at[:, :3].set(0)}
    f_n = lambda p: lm_loss(model.forward_train(p, batch, cfg), batch, cfg)
    f_s = lambda p: lm_loss(model.forward_train(p, batch, cfg_s), batch, cfg_s)
    ln, gn = jax.value_and_grad(f_n)(params)
    ls, gs = jax.value_and_grad(f_s)(params)
    assert abs(float(ln) - float(ls)) < 1e-4
    for a, b in zip(jax.tree.leaves(gn), jax.tree.leaves(gs)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-3)


def test_norm_bf16_apply_close_to_f32():
    cfg = get_smoke_config("llama3.2-1b")
    cfg_b = dataclasses.replace(cfg, norm_f32=False)
    model = get_model(cfg)
    params = model.init(KEY, cfg, 64)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab_size)}
    a = model.forward_train(params, batch, cfg)
    b = model.forward_train(params, batch, cfg_b)
    # bf16 normalize is an approximation — bounded drift, same argmax
    assert float(jnp.abs(a - b).max()) < 0.25
    agree = float(jnp.mean((jnp.argmax(a, -1) == jnp.argmax(b, -1))
                           .astype(jnp.float32)))
    assert agree > 0.95


@pytest.mark.parametrize("arch", ["deepseek-v2-lite-16b", "deepseek-v3-671b"])
def test_mla_absorbed_decode_matches_naive(arch):
    """Absorbed MLA decode == naive up-projection decode. Tolerance covers
    bf16 rounding of the naive path's materialized K/V (the absorbed path
    computes in f32 over latents and is the MORE precise one — the algebra
    itself is exact, verified separately at f32)."""
    cfg = get_smoke_config(arch)
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1e9))
    cfg_a = dataclasses.replace(cfg, mla_absorb=True)
    model = get_model(cfg)
    params = model.init(KEY, cfg, 64)
    toks = jax.random.randint(KEY, (2, 20), 0, cfg.vocab_size)
    cache = model.init_cache(cfg, 2, 64)
    _, cache = model.prefill(params, {"tokens": toks[:, :16]}, cfg, cache)
    cache_a = jax.tree.map(lambda x: x, cache)
    for t in range(16, 20):
        la, cache = model.decode_step(params, toks[:, t:t+1], cache, t, cfg)
        lb, cache_a = model.decode_step(params, toks[:, t:t+1], cache_a, t, cfg_a)
        assert float(jnp.abs(la - lb).max()) < 0.5
        a32, b32 = la.astype(jnp.float32), lb.astype(jnp.float32)
        cos = float(jnp.sum(a32 * b32) /
                    jnp.sqrt(jnp.sum(a32**2) * jnp.sum(b32**2)))
        assert cos > 0.999  # random-init near-tie argmax may flip under
        # bf16-vs-f32 precision; distribution must match


def test_grad_accum_matches_full_batch():
    cfg = get_smoke_config("llama3.2-1b")
    batch = {"tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size)}
    t1 = TrainConfig(max_seq=64)
    t4 = dataclasses.replace(t1, grad_accum=4)
    state = init_state(KEY, cfg, t1)
    s1, m1 = jax.jit(make_train_step(cfg, t1))(state, batch)
    s4, m4 = jax.jit(make_train_step(cfg, t4))(state, batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-2
    dp = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
             for a, b in zip(jax.tree.leaves(s1["params"]),
                             jax.tree.leaves(s4["params"])))
    assert dp < 5e-3
