"""End-to-end behaviour tests: training loop + checkpoint restart, the
sharding machinery (1-device mesh AOT compile — the dry-run's logic without
the 512-device flag), dual-word arithmetic, fixed-point codec."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.core import fit_scale, make_plan, quantize, dequantize
from repro.core import wideint
from repro.data.synthetic import DataConfig
from repro.train.trainer import LoopConfig, train_loop
from repro.train.train_step import TrainConfig


def test_trainer_learns_and_resumes(tmp_path):
    cfg = get_smoke_config("llama3.2-1b")
    tcfg = TrainConfig(max_seq=64)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    loop = LoopConfig(total_steps=8, ckpt_every=4, ckpt_dir=str(tmp_path),
                      log_every=100)
    _, losses = train_loop(cfg, tcfg, dcfg, loop, log=lambda s: None)
    assert losses[-1] < losses[0]  # synthetic markov data is learnable
    # restart: resumes from step 8, runs 2 more
    loop2 = dataclasses.replace(loop, total_steps=10)
    _, losses2 = train_loop(cfg, tcfg, dcfg, loop2, log=lambda s: None)
    assert len(losses2) == 2


def test_ft_trainer_survives_failstop_step(tmp_path):
    cfg = get_smoke_config("llama3.2-1b")
    tcfg = TrainConfig(max_seq=64, grad_sync="entangle")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, batch_size=4)
    loop = LoopConfig(total_steps=6, ckpt_every=100, ckpt_dir=str(tmp_path / "a"),
                      log_every=100, fail_block_at_step=3)
    _, losses_fail = train_loop(cfg, tcfg, dcfg, loop, log=lambda s: None)
    loop2 = dataclasses.replace(loop, ckpt_dir=str(tmp_path / "b"),
                                fail_block_at_step=None)
    _, losses_clean = train_loop(cfg, tcfg, dcfg, loop2, log=lambda s: None)
    np.testing.assert_allclose(losses_fail, losses_clean, atol=1e-6)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v2-lite-16b",
                                  "falcon-mamba-7b", "recurrentgemma-2b"])
@pytest.mark.parametrize("kind", ["train", "decode"])
def test_sharded_aot_compile_smoke(arch, kind):
    """The dry-run machinery on a 1-device mesh: lower + compile succeeds
    with the same sharding-rule plumbing used at 512 devices."""
    from repro.configs.base import ShapeCell
    from repro.launch.dryrun import build
    from repro.dist.sharding import axis_rules

    cfg = get_smoke_config(arch)
    cell = ShapeCell("t", 32, 2, kind)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh, axis_rules(mesh):
        fn, args, in_sh, out_sh, donate, _ = build(cfg, cell, mesh)
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*args).compile()
    assert compiled.cost_analysis() is not None


# --------------------------------------------------------------- wideint ----

@given(st.integers(-(2**62), 2**62), st.integers(-(2**31), 2**31 - 1),
       st.integers(0, 20))
@settings(max_examples=60, deadline=None)
def test_wideint_ops_match_python(big, small, shift):
    dw = wideint.widen(jnp.asarray([small], jnp.int32))
    hi = int(np.asarray(dw.hi)[0]); lo = int(np.asarray(dw.lo)[0])
    assert hi * 2**32 + lo == small
    # shift then subtract vs python ints (mod 2^64 semantics)
    sh = wideint.shl(dw, shift)
    sv = (small << shift) % 2**64
    got = (int(np.asarray(sh.hi)[0]) % 2**32) * 2**32 + int(np.asarray(sh.lo)[0])
    assert got == sv % 2**64
    d2 = wideint.sub(sh, dw)
    want = ((small << shift) - small) % 2**64
    got2 = (int(np.asarray(d2.hi)[0]) % 2**32) * 2**32 + int(np.asarray(d2.lo)[0])
    assert got2 == want


@given(st.integers(-(2**30), 2**30), st.integers(1, 31))
@settings(max_examples=60, deadline=None)
def test_wideint_extract_low_signed(val, bits):
    dw = wideint.widen(jnp.asarray([val], jnp.int32))
    got = int(np.asarray(wideint.extract_low_signed(dw, bits))[0])
    want = ((val & ((1 << bits) - 1)) ^ (1 << (bits - 1))) - (1 << (bits - 1))
    assert got == want


# ------------------------------------------------------------ fixed point ----

@given(st.integers(0, 2**31 - 1), st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_fixed_point_roundtrip_error(seed, depth):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=128).astype(np.float32))
    plan = make_plan(4, 32)
    q, scale = quantize(x, plan.max_output_magnitude, reduction_depth=depth)
    back = dequantize(q, scale)
    assert float(jnp.abs(back - x).max()) <= 1.0 / float(scale) + 1e-12
    # quantized magnitudes respect the reduction-depth budget
    assert int(jnp.abs(q).max()) * depth <= plan.max_output_magnitude
