"""Scheduler edge cases the fleet layer leans on: TokenRing capacity
semantics, RequestHandle state transitions during a fleet migration,
and deadline shedding on the injectable clock.

These are host-side policy objects (no jax): the fleet reuses them at
the router level, so their edge behavior — a full ring refusing a push,
cancel during migration, shed exemptions — is fleet correctness, not
just engine correctness.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import (ChunkScheduler, DeadlineExceeded, Fleet,
                         FleetConfig, Request, ServeConfig, TokenRing)

RNG = np.random.default_rng(31)
_PARAMS_CACHE: dict = {}


def _setup(arch: str = "llama3.2-1b", max_seq: int = 48):
    if arch not in _PARAMS_CACHE:
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg, max_seq=max_seq)
        _PARAMS_CACHE[arch] = (cfg, model, params)
    return _PARAMS_CACHE[arch]


def _scfg(**kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 48)
    kw.setdefault("prefill_buckets", (8, 16))
    kw.setdefault("prefill_chunk", 8)
    return ServeConfig(**kw)


# -- TokenRing ----------------------------------------------------------------


def test_token_ring_overflow_is_loud():
    """Capacity is the backpressure contract: the producer (engine /
    router drain) must never outrun max_new — past it the ring raises
    instead of silently dropping or overwriting tokens."""
    ring = TokenRing(3)
    for t in (1, 2, 3):
        ring.push(t)
    with pytest.raises(OverflowError, match="ring full"):
        ring.push(4)
    # consuming frees capacity — push/pop interleave indefinitely
    assert ring.pop() == 1
    ring.push(4)
    assert [ring.pop() for _ in range(3)] == [2, 3, 4]


def test_token_ring_pop_empty_and_wraparound():
    ring = TokenRing(2)
    with pytest.raises(IndexError, match="empty"):
        ring.pop()
    # head wraps: many pushes/pops through a tiny buffer stay FIFO
    out = []
    for t in range(7):
        ring.push(t)
        out.append(ring.pop())
    assert out == list(range(7))
    assert len(ring) == 0


def test_token_ring_min_capacity_one():
    ring = TokenRing(0)  # clamped to 1: even max_new=0 requests stream
    ring.push(42)
    with pytest.raises(OverflowError):
        ring.push(43)
    assert ring.pop() == 42


# -- RequestHandle across migration ------------------------------------------


def test_handle_status_transitions_during_migration():
    """The caller-visible status walks queued -> prefill -> decoding ->
    done even when the serving replica dies mid-decode: migration bounces
    the request through 'queued' (router re-entry) but never through a
    terminal state, and the handle object itself stays live."""
    cfg, _, params = _setup()
    fleet = Fleet(cfg, _scfg(), params, FleetConfig(replicas=2))
    prompt = RNG.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    h = fleet.submit(Request(rid=0, prompt=prompt, max_new=8))
    assert h.status == "queued" and not h.done
    seen = {h.status}
    while h.status != "decoding":
        fleet.step()
        seen.add(h.status)
    holder = fleet.router.records[id(h.req)].replica
    fleet.kill_replica(holder)
    fleet.step()  # heartbeat detects; request re-enters the router queue
    assert h.status in ("queued", "prefill", "decoding")
    assert not h.done, "migration must never fake a terminal state"
    fleet.run_to_completion(max_steps=300)
    seen.add(h.status)
    assert h.status == "done" and h.done
    # "prefill" is sub-step transient for a one-chunk prompt (dispatch
    # and first-decode land inside the same fleet step) — the observable
    # walk between steps is queued -> decoding -> done
    assert {"queued", "decoding", "done"} <= seen
    assert len(np.asarray(h.req.out)) == 8


def test_cancel_while_request_is_mid_migration():
    """cancel() lands in the migration window — after the replica died,
    before the request was re-dispatched. The request must finalize as
    'cancelled' with the already-streamed prefix as partial output, and
    never be re-dispatched afterwards."""
    cfg, _, params = _setup()
    fleet = Fleet(cfg, _scfg(), params, FleetConfig(replicas=2))
    prompt = RNG.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    h = fleet.submit(Request(rid=0, prompt=prompt, max_new=8))
    while h.status != "decoding":
        fleet.step()
    rec = fleet.router.records[id(h.req)]
    holder = rec.replica
    fleet.replicas[holder].transport.kill()
    fleet.router.migrate(holder)  # as the heartbeat would
    assert h.status == "queued" and rec.replica is None
    streamed = len(rec.toks)
    h.cancel()
    assert h.status == "cancelled" and h.done
    assert len(np.asarray(h.req.out)) == streamed
    fleet.run_to_completion(max_steps=50)
    assert fleet.fleet_metrics()["router_replayed"] == 0, \
        "cancelled request was re-dispatched after migration"
    # iterating a cancelled handle just yields the buffered prefix
    assert len(list(h.tokens())) == streamed


def test_cancel_every_pre_terminal_state_via_fleet():
    cfg, _, params = _setup()
    fleet = Fleet(cfg, _scfg(prefill_chunk=4), params,
                  FleetConfig(replicas=1))
    mk = lambda rid: Request(
        rid=rid, prompt=RNG.integers(0, cfg.vocab_size, 14).astype(np.int32),
        max_new=6)
    # queued (never dispatched): cancel before any step
    h_q = fleet.submit(mk(0))
    h_q.cancel()
    assert h_q.status == "cancelled" and not fleet.router.queue
    # mid-prefill: one step in (bucket 16 / chunk 4 -> 4 chunk steps)
    h_p = fleet.submit(mk(1))
    fleet.step()
    assert h_p.status == "prefill"
    h_p.cancel()
    assert h_p.status == "cancelled"
    # decoding
    h_d = fleet.submit(mk(2))
    while h_d.status != "decoding":
        fleet.step()
    h_d.cancel()
    assert h_d.status == "cancelled"
    fleet.run_to_completion(max_steps=100)
    assert fleet.fleet_metrics()["router_cancelled"] == 3
    # terminal states are cancel no-ops
    h_q.cancel()
    assert h_q.status == "cancelled"


# -- shed_expired on the injectable clock -------------------------------------


def test_shed_expired_virtual_clock_boundaries():
    """Shedding triggers strictly AFTER t_submit + deadline on the
    injected clock; deadline-less requests are never shed; the split
    preserves queue order among the kept."""
    now = [0.0]
    sched = ChunkScheduler(clock=lambda: now[0])
    mk = lambda rid, dl: Request(rid=rid, prompt=np.zeros(4, np.int32),
                                 deadline_ms=dl)
    reqs = [mk(0, 100.0), mk(1, None), mk(2, 50.0)]
    for r in reqs:
        r.t_submit = 0.0
    kept, shed = sched.shed_expired(reqs)
    assert kept == reqs and not shed
    now[0] = 0.05  # exactly request 2's deadline: NOT expired (strict >)
    kept, shed = sched.shed_expired(reqs)
    assert kept == reqs and not shed
    now[0] = 0.0501
    kept, shed = sched.shed_expired(reqs)
    assert [r.rid for r in shed] == [2]
    assert [r.rid for r in kept] == [0, 1]
    now[0] = 10.0
    kept, shed = sched.shed_expired(reqs)
    assert [r.rid for r in shed] == [0, 2]
    assert [r.rid for r in kept] == [1], "no-deadline requests never shed"


def test_fleet_sheds_expired_but_exempts_migrated():
    """Router-level shedding on the fleet's virtual clock: a queued
    request past its SLA is shed loudly (DeadlineExceeded on iteration),
    but a MIGRATED request — equally 'late' — is exempt: its admission
    already happened, so the failure must not become an SLA violation."""
    cfg, _, params = _setup()
    vclock = [0.0]
    scfg = _scfg(clock=lambda: vclock[0])
    fleet = Fleet(cfg, scfg, params, FleetConfig(replicas=2))
    prompt = RNG.integers(0, cfg.vocab_size, size=5).astype(np.int32)

    # a decoding request that will be migrated, with a deadline its
    # migration wait would blow if migrated requests were sheddable
    h_mig = fleet.submit(Request(rid=0, prompt=prompt, max_new=8,
                                 deadline_ms=100.0))
    while h_mig.status != "decoding":
        fleet.step()
    holder = fleet.router.records[id(h_mig.req)].replica
    fleet.kill_replica(holder)
    vclock[0] += 10.0  # way past every deadline
    # a fresh queued request, equally expired, submitted pre-heartbeat
    h_new = fleet.submit(Request(rid=1, prompt=prompt, max_new=4,
                                 deadline_ms=1.0))
    h_new.req.t_submit = 0.0  # submitted at t=0, now 10s late
    fleet.run_to_completion(max_steps=300)
    assert h_new.status == "shed"
    with pytest.raises(DeadlineExceeded):
        list(h_new.tokens())
    assert h_mig.status == "done", "migrated request must not be shed"
    assert len(np.asarray(h_mig.req.out)) == 8
    m = fleet.fleet_metrics()
    assert m["router_shed"] == 1 and m["router_migrated"] == 1
