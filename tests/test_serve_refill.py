"""Steady-state serving invariants: mid-flight refill, async streaming,
deadline scheduling.

  * the refill x fail-stop bitwise matrix: steady-state refill admission
    (slots recycled into the LIVE prefill chunk stream) produces tokens
    bit-identical to boundary-quantized admission, per request, for
    dense/ssm/hybrid x ft_scope head/all x an injected fail-stop in every
    group — admission TIMING must never change tokens or break the
    entangled roll-forward;
  * refill genuinely refills: the matrix runs plan new batches while
    earlier batches are still mid-chunk (metrics['refill_admissions']);
  * refill reuses the startup-compiled plans: no new registry entries, no
    CompiledPlans lookup misses, after a full refill wave;
  * recycled-row zeroing rides the landing scatter: ONE _scatter_rows
    dispatch per steady-state step (trace-count), with zero rows merged;
  * the async frontend: submit() returns a handle whose iterator streams
    exactly the request's tokens (driving engine.step() on demand);
    cancel() works queued / mid-prefill / decoding; deadline_ms sheds
    loudly (DeadlineExceeded) under a fake clock; max_queue rejects with
    a typed AdmissionRejected; EDF orders admission by deadline; EOS ends
    a request early.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import (AdmissionRejected, DeadlineExceeded, Request,
                         ServeConfig, ServeEngine)

RNG = np.random.default_rng(31)
_PARAMS_CACHE: dict = {}


def _setup(arch: str, max_seq: int = 48):
    if arch not in _PARAMS_CACHE:
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg, max_seq=max_seq)
        _PARAMS_CACHE[arch] = (cfg, model, params)
    return _PARAMS_CACHE[arch]


def _prompts(cfg, lengths):
    return [RNG.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
            for n in lengths]


# staggered wave engineered so refill really happens mid-flight: the
# length-12 prompt buckets to 16 (2 chunks of 8), and while it is being
# chunked the short early finishers (staggered max_new) free slots that
# the tail of the queue refills — impossible under boundary admission.
LENGTHS = [5, 6, 12, 3, 4, 6]
MAX_NEW = [1, 2, 3, 2, 1, 2]
BUCKETS = (8, 16)


def _run(cfg, params, *, refill, scope="head", ft=True, failed_group=None,
         lengths=LENGTHS, max_new=MAX_NEW):
    global RNG
    RNG = np.random.default_rng(31)  # same prompts for every variant
    scfg = ServeConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                       prefill_buckets=BUCKETS, refill=refill,
                       **({"ft_mode": "entangle", "ft_M": 4,
                           "ft_scope": scope} if ft else {}))
    eng = ServeEngine(cfg, scfg, params)
    for r, p in enumerate(_prompts(cfg, lengths)):
        eng.submit(Request(rid=r, prompt=p, max_new=max_new[r]))
    eng.run_to_completion(max_steps=500, failed_group=failed_group)
    return {r.rid: np.asarray(r.out) for r in eng.done}, eng


@pytest.mark.parametrize("scope", ["head", "all"])
@pytest.mark.parametrize(
    "arch", ["llama3.2-1b", "falcon-mamba-7b", "recurrentgemma-2b"])
def test_refill_failstop_bitwise_matrix(arch, scope):
    """Refill vs boundary admission, healthy AND with a fail-stop injected
    into every group: identical tokens per request. Quantization is per
    row and slot -> group is positional, so WHEN a slot was refilled can
    never move another request's integer grid — admission timing is
    token-transparent and the roll-forward stays bit-exact."""
    cfg, _, params = _setup(arch)
    boundary, beng = _run(cfg, params, refill=False, scope=scope)
    assert set(boundary) == set(range(len(LENGTHS)))
    assert beng.metrics["refill_admissions"] == 0  # truly boundary
    for fg in range(4):
        out, eng = _run(cfg, params, refill=True, scope=scope,
                        failed_group=fg)
        assert eng.metrics["refill_admissions"] > 0, \
            "matrix never exercised a mid-flight refill"
        for r in boundary:
            np.testing.assert_array_equal(
                boundary[r], out[r],
                err_msg=f"{arch} scope={scope} failed_group={fg} rid={r} "
                        f"(refill or roll-forward changed tokens)")


def test_refill_reuses_compiled_plans_no_retrace():
    """A refill wave must replay the census'd [Bp, bucket] chunk programs:
    zero CompiledPlans lookup misses and zero NEW registry entries after
    the wave — refill never retraces and never creates a plan."""
    cfg, _, params = _setup("llama3.2-1b")
    RNGsave = np.random.default_rng(31)
    scfg = ServeConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                       prefill_buckets=BUCKETS,
                       ft_mode="entangle", ft_M=4, ft_scope="all")
    eng = ServeEngine(cfg, scfg, params)
    n_entries = len(eng.registry.census())
    for r, n in enumerate(LENGTHS):
        eng.submit(Request(
            rid=r, prompt=RNGsave.integers(0, cfg.vocab_size, n)
            .astype(np.int32), max_new=MAX_NEW[r]))
    eng.run_to_completion(max_steps=500)
    assert eng.metrics["refill_admissions"] > 0
    assert eng.plans.misses == 0, \
        "refill requested a shape the startup census missed"
    assert len(eng.registry.census()) == n_entries, \
        "refill created new plan-registry entries (lazy fallback ran)"


def test_recycle_zeroing_rides_landing_scatter():
    """Satellite fix: recycled-row zeroing and the admission insert share
    ONE batched _scatter_rows dispatch — a landing chunk's scatter carries
    the pending zero rows in its spare capacity (zero mask)."""
    cfg, _, params = _setup("llama3.2-1b")
    eng = ServeEngine(cfg, ServeConfig(max_batch=4, max_seq=48), params)
    for r, p in enumerate(_prompts(cfg, [5, 6])):
        eng.submit(Request(rid=r, prompt=p, max_new=2))
    before = eng.scatter_calls
    eng.step()  # land A+B (scatter 1), decode to max_new -> both recycle
    assert eng.scatter_calls == before + 1
    eng.submit(Request(rid=2, prompt=_prompts(cfg, [4])[0], max_new=2))
    eng.step()  # C lands on one freed slot; the OTHER dirty slot rides
    #             the same scatter as a zero row — no extra dispatch
    assert eng.scatter_calls == before + 2
    assert eng.metrics["merged_zero_rows"] == 1
    assert eng.scatter_calls == eng.metrics["landings"], \
        "a separate recycle flush ran despite landing spare capacity"
    done = eng.run_to_completion(max_steps=50)
    assert len(done) == 3


def test_handle_iterator_streams_and_drives_engine():
    """submit() returns a handle; iterating it drives engine.step() on
    demand and yields exactly the tokens the request finished with."""
    cfg, _, params = _setup("llama3.2-1b")
    eng = ServeEngine(cfg, ServeConfig(max_batch=2, max_seq=48), params)
    h0 = eng.submit(Request(rid=0, prompt=_prompts(cfg, [5])[0], max_new=4))
    h1 = eng.submit(Request(rid=1, prompt=_prompts(cfg, [7])[0], max_new=6))
    streamed0 = list(h0)  # no manual step() calls anywhere
    assert h0.done and h0.status == "done"
    np.testing.assert_array_equal(np.asarray(streamed0), h0.req.out)
    assert len(streamed0) == 4
    streamed1 = list(h1.tokens())
    np.testing.assert_array_equal(np.asarray(streamed1), h1.req.out)
    assert h1.result() is h1.req and len(streamed1) == 6


def test_cancel_in_every_state():
    """cancel() queued: leaves the queue untouched-by-compute; cancel()
    mid-prefill: the row never claims a slot and its reservation frees
    immediately; cancel() decoding: partial output finalizes and the slot
    recycles for the next tenant."""
    cfg, _, params = _setup("llama3.2-1b")
    eng = ServeEngine(cfg, ServeConfig(max_batch=2, max_seq=48,
                                       prefill_chunk=8), params)
    # queued: cancel before any step
    hq = eng.submit(Request(rid=0, prompt=_prompts(cfg, [5])[0], max_new=4))
    hq.cancel()
    assert hq.status == "cancelled" and not eng.queue
    assert list(hq) == [] and len(hq.req.out) == 0
    # mid-prefill: bucket 32 -> 4 chunks; cancel after the first chunk
    hp = eng.submit(Request(rid=1, prompt=_prompts(cfg, [30])[0], max_new=4))
    eng.step()
    assert hp.status == "prefill" and eng._inflight
    hp.cancel()
    assert hp.status == "cancelled"
    # the engine still serves others; the cancelled row never lands
    hd = eng.submit(Request(rid=2, prompt=_prompts(cfg, [6])[0], max_new=5))
    done = eng.run_to_completion(max_steps=100)
    assert [r.rid for r in done] == [2] and len(hd.req.out) == 5
    assert all(s is None for s in eng.slots) and not eng._reserved
    # decoding: cancel after a couple of generated tokens
    hx = eng.submit(Request(rid=3, prompt=_prompts(cfg, [5])[0], max_new=16))
    eng.step()
    eng.step()
    assert hx.status == "decoding"
    hx.cancel()
    assert hx.status == "cancelled" and 1 <= len(hx.req.out) < 16
    assert all(s is None for s in eng.slots)
    assert eng.metrics["cancelled"] == 3


def test_deadline_shed_is_loud():
    """A queued request whose deadline_ms lapses before admission is shed
    BEFORE any prefill compute is spent on it; iterating its handle raises
    DeadlineExceeded. Admitted requests are never shed."""
    cfg, _, params = _setup("llama3.2-1b")
    now = [0.0]
    eng = ServeEngine(cfg, ServeConfig(max_batch=1, max_seq=48,
                                       clock=lambda: now[0]), params)
    busy = eng.submit(Request(rid=0, prompt=_prompts(cfg, [5])[0],
                              max_new=8, deadline_ms=50.0))
    eng.step()  # rid0 admitted: its deadline no longer applies
    hs = eng.submit(Request(rid=1, prompt=_prompts(cfg, [5])[0],
                            max_new=4, deadline_ms=10.0))
    now[0] = 1.0  # 1000 ms later: rid1's 10 ms budget is long gone
    pre = eng.prefill_calls
    eng.step()
    assert hs.status == "shed" and eng.prefill_calls == pre
    assert eng.metrics["shed"] == 1
    with pytest.raises(DeadlineExceeded, match="rid=1"):
        list(hs)
    # the admitted request survives its own (lapsed) deadline
    assert busy.result().status == "done" and len(busy.req.out) == 8


def test_admission_rejected_at_saturation():
    """max_queue bounds the wait queue with a typed rejection."""
    cfg, _, params = _setup("llama3.2-1b")
    eng = ServeEngine(cfg, ServeConfig(max_batch=1, max_seq=48,
                                       max_queue=2), params)
    for r in range(2):
        eng.submit(Request(rid=r, prompt=_prompts(cfg, [5])[0], max_new=2))
    with pytest.raises(AdmissionRejected, match="max_queue"):
        eng.submit(Request(rid=2, prompt=_prompts(cfg, [5])[0], max_new=2))
    assert eng.metrics["rejected"] == 1
    assert eng.metrics["queue_depth_peak"] == 2
    done = eng.run_to_completion(max_steps=100)
    assert sorted(r.rid for r in done) == [0, 1]


def test_edf_orders_admission_by_deadline():
    """Earliest-deadline-first: with one slot, the tightest deadline is
    admitted first however late it was submitted; deadline-less requests
    rank last (FIFO among themselves — the legacy order)."""
    cfg, _, params = _setup("llama3.2-1b")
    now = [0.0]
    eng = ServeEngine(cfg, ServeConfig(max_batch=1, max_seq=48,
                                       clock=lambda: now[0]), params)
    eng.submit(Request(rid=0, prompt=_prompts(cfg, [5])[0], max_new=1))
    eng.submit(Request(rid=1, prompt=_prompts(cfg, [5])[0], max_new=1,
                       deadline_ms=1e6))
    eng.submit(Request(rid=2, prompt=_prompts(cfg, [5])[0], max_new=1,
                       deadline_ms=1e3))
    done = eng.run_to_completion(max_steps=100)
    assert [r.rid for r in done] == [2, 1, 0]


def test_eos_token_ends_request_early():
    """Request.eos_token stops decode at the first EOS emission — the
    slot recycles into the refill stream right then, not at max_new."""
    cfg, _, params = _setup("llama3.2-1b")
    prompt = _prompts(cfg, [6])[0]
    eng = ServeEngine(cfg, ServeConfig(max_batch=1, max_seq=48), params)
    eng.submit(Request(rid=0, prompt=prompt.copy(), max_new=8))
    ref = eng.run_to_completion(max_steps=50)[0].out
    eos = int(ref[2])  # greedy decode is deterministic: rerun stops here
    stop = int(np.argmax(ref == eos))  # first occurrence (index <= 2)
    eng2 = ServeEngine(cfg, ServeConfig(max_batch=1, max_seq=48), params)
    eng2.submit(Request(rid=0, prompt=prompt.copy(), max_new=8,
                        eos_token=eos))
    out = eng2.run_to_completion(max_steps=50)[0].out
    np.testing.assert_array_equal(out, ref[: stop + 1])
