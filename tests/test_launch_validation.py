"""launch/serve argument validation: FT/admission misconfigurations must
die at PARSE time with a clear message — not deep inside engine startup
or a traced step."""
import sys

import pytest

from repro.launch import serve as launch_serve


def _argv(*extra):
    return ["prog", "--arch", "llama3.2-1b", "--smoke", *extra]


@pytest.mark.parametrize("extra,msg", [
    (["--failed-group", "1"], "requires --ft-mode entangle"),
    (["--ft-mode", "entangle", "--failed-group", "4"], "--ft-M"),
    (["--ft-mode", "entangle", "--failed-group", "7", "--ft-M", "4"],
     "--ft-M"),
    (["--ft-mode", "entangle", "--ft-M", "3"], "divisible"),  # max_batch 4
    (["--ft-mode", "entangle", "--ft-M", "2", "--max-batch", "4"], ">= 3"),
    (["--ft-scope", "everything"], "invalid choice"),
    (["--prefill-chunk", "-3"], "prefill-chunk"),
    (["--token-budget", "-8"], "--token-budget"),
    (["--token-budget", "16"], "requires --prefill-chunk > 0"),
    (["--token-budget", "12", "--prefill-chunk", "8"], "multiple"),
    (["--token-budget", "64", "--prefill-chunk", "8", "--max-batch", "4"],
     "max-batch"),
    (["--prefill-buckets", "8,banana"], "comma-separated"),
    (["--prefill-buckets", "8,512", "--max-seq", "64"], "max-seq"),
    (["--arrival-rate", "-1.5"], "--arrival-rate"),
    (["--deadline-ms", "0"], "--deadline-ms"),
    (["--deadline-ms", "-250"], "--deadline-ms"),
    (["--replicas", "0"], "--replicas"),
    (["--kill-replica-at", "5"], "--replicas >= 2"),  # default pool of 1
    (["--replicas", "4", "--kill-replica-at", "20000"], "drain bound"),
    (["--replicas", "4", "--kill-replica-at", "5", "--kill-replica", "7"],
     "initial pool"),
    (["--replicas", "2", "--kill-replica", "1"], "--kill-replica-at"),
    (["--replicas", "4", "--max-replicas", "2"], "--max-replicas"),
    (["--scale-up-depth", "0"], "--scale-up-depth"),
])
def test_bad_args_fail_at_parse_time(monkeypatch, capsys, extra, msg):
    monkeypatch.setattr(sys, "argv", _argv(*extra))
    with pytest.raises(SystemExit) as e:
        launch_serve.main()
    assert e.value.code == 2, "argparse .error exits with code 2"
    assert msg in capsys.readouterr().err


def test_steady_state_flags_accepted_at_parse_time(monkeypatch, capsys):
    """Valid --arrival-rate / --deadline-ms / --no-refill combinations
    parse cleanly: the parser takes them and dies on the NEXT invalid
    flag, proving their validation passed."""
    monkeypatch.setattr(sys, "argv", _argv(
        "--arrival-rate", "4.0", "--deadline-ms", "500", "--no-refill",
        "--prefill-chunk", "-1"))
    with pytest.raises(SystemExit) as e:
        launch_serve.main()
    assert e.value.code == 2
    assert "prefill-chunk" in capsys.readouterr().err


def test_token_budget_accepted_at_parse_time(monkeypatch, capsys):
    """A valid --token-budget / --prefill-chunk pairing parses cleanly:
    the parser takes it and dies on the NEXT invalid flag, proving the
    packed-geometry validation passed."""
    monkeypatch.setattr(sys, "argv", _argv(
        "--token-budget", "32", "--prefill-chunk", "8",
        "--arrival-rate", "-1"))
    with pytest.raises(SystemExit) as e:
        launch_serve.main()
    assert e.value.code == 2
    assert "arrival-rate" in capsys.readouterr().err


def test_fleet_flags_accepted_at_parse_time(monkeypatch, capsys):
    """A valid fleet configuration — pool of 4, kill schedule inside the
    drain bound, autoscaling bounds above the pool — parses cleanly: the
    parser takes it and dies on the NEXT invalid flag, proving every
    fleet cross-flag contract passed."""
    monkeypatch.setattr(sys, "argv", _argv(
        "--replicas", "4", "--kill-replica-at", "12", "--kill-replica", "2",
        "--max-replicas", "6", "--scale-up-depth", "3",
        "--prefill-chunk", "-1"))
    with pytest.raises(SystemExit) as e:
        launch_serve.main()
    assert e.value.code == 2
    assert "prefill-chunk" in capsys.readouterr().err


def test_new_scopes_accepted_at_parse_time(monkeypatch, capsys):
    """'out' and 'moe' are real choices now — the parser takes them and
    dies on the NEXT invalid flag, proving scope validation passed."""
    for scope in ("out", "moe", "all"):
        monkeypatch.setattr(sys, "argv", _argv(
            "--ft-mode", "entangle", "--ft-scope", scope,
            "--prefill-chunk", "-1"))
        with pytest.raises(SystemExit) as e:
            launch_serve.main()
        assert e.value.code == 2
        assert "prefill-chunk" in capsys.readouterr().err
