"""Minimal stand-in for the ``hypothesis`` package (dependency gate).

The container image does not ship hypothesis and installing packages is not
allowed, so ``tests/conftest.py`` registers this module as ``hypothesis``
in ``sys.modules`` when (and only when) the real package is unavailable.

Implements exactly the surface the test suite uses — ``given``, ``settings``
and the ``integers`` / ``sampled_from`` / ``composite`` strategies — as a
seeded random sweep (no shrinking, no database). Deterministic across runs:
every test draws from a PRNG seeded with the test function's name.
"""
from __future__ import annotations

import random
import zlib


class _Strategy:
    def __init__(self, draw_fn):
        self._draw = draw_fn

    def map(self, f):
        return _Strategy(lambda rnd: f(self._draw(rnd)))

    def filter(self, pred, _tries: int = 100):
        def draw(rnd):
            for _ in range(_tries):
                v = self._draw(rnd)
                if pred(v):
                    return v
            raise ValueError("mini-hypothesis: filter predicate never satisfied")

        return _Strategy(draw)


class strategies:
    """Namespace mirror of ``hypothesis.strategies``."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rnd: rnd.randint(min_value, max_value))

    @staticmethod
    def sampled_from(seq) -> _Strategy:
        items = list(seq)
        return _Strategy(lambda rnd: rnd.choice(items))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rnd: bool(rnd.getrandbits(1)))

    @staticmethod
    def composite(fn):
        def builder(*args, **kwargs):
            def draw_fn(rnd):
                return fn(lambda strat: strat._draw(rnd), *args, **kwargs)

            return _Strategy(draw_fn)

        return builder


class settings:
    """Accepts and stores the kwargs the suite uses; others are ignored."""

    def __init__(self, max_examples: int = 100, deadline=None, **_ignored):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._mini_settings = self
        return fn


def given(*strats: _Strategy, **kwstrats: _Strategy):
    def deco(fn):
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_mini_settings", None) or getattr(
                fn, "_mini_settings", None
            )
            n = cfg.max_examples if cfg else 25
            rnd = random.Random(zlib.adler32(fn.__name__.encode()))
            for _ in range(n):
                vals = [s._draw(rnd) for s in strats]
                kvals = {k: s._draw(rnd) for k, s in kwstrats.items()}
                fn(*args, *vals, **kwargs, **kvals)

        # no functools.wraps: pytest would follow __wrapped__ to the original
        # signature and misread the drawn arguments as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # pytest plugins (anyio) introspect fn.hypothesis.inner_test
        wrapper.hypothesis = type("_HypothesisStub", (), {"inner_test": fn})()
        return wrapper

    return deco


class HealthCheck:  # referenced by some suites; values are inert here
    too_slow = data_too_large = filter_too_much = None
