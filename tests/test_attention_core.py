"""Flash attention vs materialized oracle: forward, custom VJP, masks,
padding, GQA grouping, rolling-window decode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import attention_core as AC

KEY = jax.random.PRNGKey(1)


def _qkv(B=2, Hkv=2, G=3, T=96, S=96, dk=16, dv=24):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (B, Hkv, G, T, dk)),
            jax.random.normal(ks[1], (B, Hkv, S, dk)),
            jax.random.normal(ks[2], (B, Hkv, S, dv)))


@pytest.mark.parametrize("kind,window", [("causal", 0), ("window", 24), ("full", 0)])
@pytest.mark.parametrize("qb,kb", [(32, 32), (64, 32), (32, 48)])
def test_flash_matches_oracle(kind, window, qb, kb):
    q, k, v = _qkv()
    S = k.shape[2]
    ref = AC.attend(q, k, v, kind=kind, window=window)
    info = AC.MaskInfo(kind, window, S)
    out = AC.flash_attention(
        AC._pad_axis(q, 3, qb), AC._pad_axis(k, 2, kb), AC._pad_axis(v, 2, kb),
        info, 0.25, qb, kb)[:, :, :, : q.shape[3]]
    np.testing.assert_allclose(np.asarray(out), np.asarray(
        AC.attend(q, k, v, kind=kind, window=window, scale=0.25)), atol=2e-5)
    del ref


@pytest.mark.parametrize("kind,window", [("causal", 0), ("window", 24)])
def test_flash_custom_vjp_matches(kind, window):
    q, k, v = _qkv(T=64, S=64)
    info = AC.MaskInfo(kind, window, 64)

    def f_ref(q, k, v):
        return (AC.attend(q, k, v, kind=kind, window=window) ** 2).sum()

    def f_fl(q, k, v):
        qp = AC._pad_axis(q, 3, 32)
        kp, vp = AC._pad_axis(k, 2, 32), AC._pad_axis(v, 2, 32)
        o = AC.flash_attention(qp, kp, vp, info, 1.0 / 4.0, 32, 32)
        return (o[:, :, :, :64] ** 2).sum()

    # same scale for both
    g_ref = jax.grad(lambda q, k, v: (AC.attend(q, k, v, kind=kind,
                     window=window, scale=0.25) ** 2).sum(), (0, 1, 2))(q, k, v)
    g_fl = jax.grad(f_fl, (0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_fl):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4)


def test_unpadded_kv_tail_is_masked():
    q, k, v = _qkv(T=40, S=40)
    info_tail = AC.MaskInfo("causal", 0, 40)
    out = AC.flash_attention(
        AC._pad_axis(q, 3, 32), AC._pad_axis(k, 2, 32), AC._pad_axis(v, 2, 32),
        info_tail, 0.25, 32, 32)[:, :, :, :40]
    ref = AC.attend(q, k, v, kind="causal", scale=0.25)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_rolling_buffer_positions():
    """attend_decode honors arbitrary slot->absolute-position maps."""
    q, k, v = _qkv(T=1, S=8)
    # rolling buffer: slots hold positions [8, 9, 2..7] (window 8, pos 9)
    abs_pos = jnp.asarray([8, 9, 2, 3, 4, 5, 6, 7])
    out = AC.attend_decode(q, k, v, abs_pos=abs_pos)
    # equivalent ordered computation
    order = jnp.argsort(abs_pos)
    out2 = AC.attend_decode(q, k[:, :, order], v[:, :, order],
                            abs_pos=abs_pos[order])
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)
    # invalid slots are excluded
    abs_inv = abs_pos.at[3].set(-1)
    out3 = AC.attend_decode(q, k, v, abs_pos=abs_inv)
    assert np.abs(np.asarray(out3) - np.asarray(out)).max() > 1e-6
