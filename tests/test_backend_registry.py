"""Kernel backend registry (kernels/ops) invariants.

  * the three builtin backends are registered and the platform rule picks
    ``interpret_cpu`` off-TPU; the legacy ``interpret=`` flag still maps
    onto backend names;
  * a backend registered from OUTSIDE ops.py (no edits to the module)
    receives the wrapper's padded operands and resolved blocks — ops
    routes to it by name and via the process default;
  * autotune keys are namespaced by backend name, so a port tunes into
    its own cache rows and can never clobber (or steal) another backend's
    winners;
  * unregistering restores the platform default and unknown names fail
    loudly;
  * the registration contract is enforced (missing required ops rejected)
    and the documented Triton/CUDA stub raises NotImplementedError with
    porting guidance rather than computing garbage.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.plan import make_plan
from repro.kernels import autotune, ops, ref

PLAN = make_plan(4, 32)
RNG = np.random.default_rng(3)


def _cg(B=10, K=24, N=16):
    c = jnp.asarray(RNG.integers(-40, 40, size=(4, B, K)).astype(np.int32))
    g = jnp.asarray(RNG.integers(-25, 25, size=(K, N)).astype(np.int32))
    return c, g


def test_builtin_backends_and_resolution():
    assert {"pallas_tpu", "interpret_cpu", "reference"} <= set(
        ops.backend_names())
    # off-TPU platform rule (CI runs on CPU)
    assert ops.resolve_backend() == "interpret_cpu"
    assert ops.resolve_backend(None, True) == "interpret_cpu"
    assert ops.resolve_backend(None, False) == "pallas_tpu"
    assert ops.resolve_backend("reference") == "reference"
    with pytest.raises(KeyError, match="no kernel backend"):
        ops.resolve_backend("cuda_rocm_fpga")


def test_reference_backend_matches_interpret():
    c, g = _cg()
    for r in range(PLAN.M):
        a = ops.entangled_matmul(c, g, PLAN, fuse_epilogue=True, failed=r,
                                 bb=16, bn=32, bk=32, backend="interpret_cpu")
        b = ops.entangled_matmul(c, g, PLAN, fuse_epilogue=True, failed=r,
                                 bb=16, bn=32, bk=32, backend="reference")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_registered_fake_backend_routes_and_namespaces(tmp_path, monkeypatch):
    """Register a spying backend WITHOUT touching ops.py: ops must route
    calls to it (explicitly and as process default), autotune must key its
    winners under the backend's own namespace, and unregistering must
    restore the platform default."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    cache = autotune.reset_cache(str(tmp_path / "at.json"))
    calls = []

    def spy_emm(c, g, *, plan, fuse_epilogue, failed, blocks, packed):
        assert packed is False  # unpacked int32-container weights here
        calls.append(("entangled_matmul", c.shape, dict(blocks)))
        if fuse_epilogue:
            return ref.entangled_matmul_fused_ref(c, g, plan, r=failed)
        return ref.entangled_matmul_ref(c, g, plan.l)

    impls = {"entangled_matmul": spy_emm,
             "entangled_conv1d": lambda *a, **k: (_ for _ in ()).throw(
                 AssertionError("conv not exercised")),
             "entangled_matmul_grouped": lambda *a, **k: (_ for _ in ()).throw(
                 AssertionError("grouped not exercised"))}
    try:
        ops.register_backend("fake_accel", impls, interpret=True)
        c, g = _cg()
        want = np.asarray(ops.entangled_matmul(
            c, g, PLAN, fuse_epilogue=True, bb=16, bn=32, bk=32,
            backend="interpret_cpu"))

        # explicit routing: the spy sees padded operands + resolved blocks
        got = ops.entangled_matmul(c, g, PLAN, fuse_epilogue=True,
                                   bb=16, bn=32, bk=32, backend="fake_accel")
        assert calls and calls[-1][0] == "entangled_matmul"
        assert calls[-1][1] == (4, 16, 32)  # padded to bb=16, bk=32
        assert calls[-1][2] == {"bb": 16, "bn": 32, "bk": 32}
        np.testing.assert_array_equal(np.asarray(got), want)

        # process-default routing
        ops.set_default_backend("fake_accel")
        n0 = len(calls)
        ops.entangled_matmul(c, g, PLAN, fuse_epilogue=True,
                             bb=16, bn=32, bk=32)
        assert len(calls) == n0 + 1
        assert ops.resolve_backend() == "fake_accel"

        # autotune namespacing: winners land under the backend's own name
        ops.entangled_matmul(c, g, PLAN, fuse_epilogue=True, blocks="auto",
                             backend="fake_accel")
        keys = [k for k in cache._mem if "|fake_accel|" in k]
        assert keys, f"no fake_accel-namespaced winners in {list(cache._mem)}"
        assert not any("|interpret_cpu|" in k for k in cache._mem), \
            "fake backend sweep leaked into the interpret_cpu namespace"
    finally:
        ops.unregister_backend("fake_accel")
        autotune.reset_cache(None)

    # unregistering restored the platform default and dropped the name
    assert ops.resolve_backend() == "interpret_cpu"
    with pytest.raises(KeyError):
        ops.get_backend("fake_accel")


def test_register_backend_contract_and_triton_stub():
    with pytest.raises(ValueError, match="missing required ops"):
        ops.register_backend("half_port", {"entangled_matmul": lambda: 0})
    assert "half_port" not in ops.backend_names()

    stub = ops.triton_cuda_stub()
    assert set(stub) == set(ops.REQUIRED_OPS)
    ops.register_backend("triton_cuda", stub, interpret=False)
    try:
        c, g = _cg()
        with pytest.raises(NotImplementedError, match="not ported yet"):
            ops.entangled_matmul(c, g, PLAN, fuse_epilogue=True,
                                 bb=16, bn=32, bk=32, backend="triton_cuda")
    finally:
        ops.unregister_backend("triton_cuda")
