"""Batched serving engine invariants.

  * the batched engine (ONE jitted decode call per step, slot-batched cache,
    per-slot position vector) is bit-identical to the per-slot reference
    engine on greedy decode, across slot recycling;
  * with ft_mode='entangle' the decoded tokens are bit-identical with and
    without an injected single-group fail-stop (the paper's roll-forward on
    the real hot path);
  * exactly one jitted decode call per engine step, however many slots are
    active;
  * requests generate exactly ``max_new`` tokens (no decode-then-truncate);
  * mixed per-row positions in one decode call match per-row scalar decode
    bitwise at the model level (the new decode contract).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import PerSlotEngine, Request, ServeConfig, ServeEngine

RNG = np.random.default_rng(11)
_PARAMS_CACHE: dict = {}


def _setup(arch: str, max_seq: int = 48):
    if arch not in _PARAMS_CACHE:
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg, max_seq=max_seq)
        _PARAMS_CACHE[arch] = (cfg, model, params)
    return _PARAMS_CACHE[arch]


def _prompts(n, vocab, lo=4, hi=9):
    return [RNG.integers(0, vocab, size=int(RNG.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


def _run(engine_cls, cfg, scfg, params, prompts, max_new=5,
         failed_group=None):
    eng = engine_cls(cfg, scfg, params)
    for r, p in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=p.copy(), max_new=max_new))
    steps = 0
    while (eng.queue or any(s is not None for s in eng.slots)) and steps < 500:
        if failed_group is None:
            eng.step()
        else:
            eng.step(failed_group=failed_group)
        steps += 1
    return {r.rid: np.asarray(r.out) for r in eng.done}, eng, steps


@pytest.mark.parametrize("arch", ["llama3.2-1b", "falcon-mamba-7b"])
def test_batched_bit_identical_to_per_slot(arch):
    """10 requests through 4 slots: recycling, ragged prompt lengths, ragged
    completion — greedy outputs must match the per-slot engine bitwise."""
    cfg, _, params = _setup(arch)
    prompts = _prompts(10, cfg.vocab_size)
    scfg = ServeConfig(max_batch=4, max_seq=48)
    ref, ref_eng, _ = _run(PerSlotEngine, cfg, scfg, params, prompts)
    out, eng, steps = _run(ServeEngine, cfg, scfg, params, prompts)
    assert set(ref) == set(out) == set(range(10))
    for r in ref:
        np.testing.assert_array_equal(ref[r], out[r], err_msg=f"rid={r}")
    # batching must actually batch: far fewer decode dispatches
    assert eng.decode_calls < ref_eng.decode_calls


def test_one_jitted_decode_call_per_step():
    cfg, _, params = _setup("llama3.2-1b")
    eng = ServeEngine(cfg, ServeConfig(max_batch=4, max_seq=48), params)
    for r, p in enumerate(_prompts(4, cfg.vocab_size)):
        eng.submit(Request(rid=r, prompt=p, max_new=4))
    for expected in range(1, 4):
        eng.step()
        assert eng.decode_calls == expected  # 4 active slots, ONE call


def test_ft_failstop_bit_identical():
    """ft_mode='entangle': tokens with an injected fail-stop in ANY single
    group equal the healthy run bitwise — per-step in-kernel roll-forward."""
    cfg, _, params = _setup("llama3.2-1b")
    prompts = _prompts(8, cfg.vocab_size)
    scfg = ServeConfig(max_batch=4, max_seq=48, ft_mode="entangle", ft_M=4)
    healthy, _, _ = _run(ServeEngine, cfg, scfg, params, prompts)
    for fg in range(4):
        injected, _, _ = _run(ServeEngine, cfg, scfg, params, prompts,
                              failed_group=fg)
        for r in healthy:
            np.testing.assert_array_equal(
                healthy[r], injected[r], err_msg=f"failed_group={fg} rid={r}")


@pytest.mark.parametrize("scope", ["head", "qkv", "mlp", "out", "all"])
@pytest.mark.parametrize(
    "arch", ["llama3.2-1b", "falcon-mamba-7b", "recurrentgemma-2b"])
def test_ft_scope_failstop_bit_identical(arch, scope):
    """The scope x failure matrix (dense/ssm/hybrid x
    head/qkv/mlp/out/all x every group): with protection widened to the
    in-model QKV/MLP/output projections (repro.ft), a fail-stop injected
    on EVERY step into ANY single group — reaching every protected GEMM
    of the decode step and the admission head — still decodes
    bit-identically to the healthy run at the same scope, via the
    per-site in-kernel roll-forward."""
    cfg, _, params = _setup(arch)
    prompts = _prompts(5, cfg.vocab_size)
    scfg = ServeConfig(max_batch=4, max_seq=48, ft_mode="entangle", ft_M=4,
                       ft_scope=scope)
    healthy, _, _ = _run(ServeEngine, cfg, scfg, params, prompts, max_new=3)
    assert set(healthy) == set(range(5))
    for fg in range(4):
        injected, _, _ = _run(ServeEngine, cfg, scfg, params, prompts,
                              max_new=3, failed_group=fg)
        for r in healthy:
            np.testing.assert_array_equal(
                healthy[r], injected[r],
                err_msg=f"{arch} scope={scope} failed_group={fg} rid={r}")


@pytest.mark.parametrize("scope", ["moe", "all"])
def test_ft_moe_grouped_failstop_bit_identical(scope):
    """MoE coverage: with scope 'moe' (and 'all', which now includes it)
    the per-expert batched GEMMs run through the GROUPED entangled kernel
    on every decode step — a fail-stop in any single group rolls forward
    bit-identically across all experts at once, with routing (router site)
    and capacity drops identical between the healthy and injected runs."""
    cfg, _, params = _setup("deepseek-v2-lite-16b")
    prompts = _prompts(5, cfg.vocab_size)
    scfg = ServeConfig(max_batch=4, max_seq=48, ft_mode="entangle", ft_M=4,
                       ft_scope=scope)
    healthy, eng, _ = _run(ServeEngine, cfg, scfg, params, prompts,
                           max_new=3)
    assert set(healthy) == set(range(5))
    # the grouped sites actually compiled into the AOT plan set
    assert "moe" in eng.plans.categories()
    assert any(p.grouped for p in eng.plans)
    for fg in range(4):
        injected, _, _ = _run(ServeEngine, cfg, scfg, params, prompts,
                              max_new=3, failed_group=fg)
        for r in healthy:
            np.testing.assert_array_equal(
                healthy[r], injected[r],
                err_msg=f"scope={scope} failed_group={fg} rid={r}")


def test_exactly_max_new_tokens():
    """Off-by-one fix: exactly max_new tokens generated, none discarded —
    including max_new=1 (prefill-only request, finished at admission)."""
    cfg, _, params = _setup("llama3.2-1b")
    for engine_cls in (ServeEngine, PerSlotEngine):
        eng = engine_cls(cfg, ServeConfig(max_batch=2, max_seq=48), params)
        for r, mn in enumerate([1, 3, 6]):
            eng.submit(Request(rid=r, prompt=_prompts(1, cfg.vocab_size)[0],
                               max_new=mn))
        done = eng.run_to_completion()
        assert sorted(len(r.out) for r in done) == [1, 3, 6]
        # every generated token is kept: the slot bookkeeping never holds
        # more than max_new tokens (the seed decoded max_new + 1)
        for r in done:
            assert r.out is not None and len(r.out) == r.max_new


def test_capacity_overflow_rejected_loudly():
    """prompt + max_new > max_seq must raise at submit (past max_seq the
    cache write would silently drop K/V and corrupt outputs)."""
    cfg, _, params = _setup("llama3.2-1b")
    for engine_cls in (ServeEngine, PerSlotEngine):
        eng = engine_cls(cfg, ServeConfig(max_batch=2, max_seq=48), params)
        with pytest.raises(ValueError, match="max_seq"):
            eng.submit(Request(rid=0,
                               prompt=np.zeros(8, np.int32), max_new=48))


def test_recycled_slot_is_pristine():
    """Explicit slot recycling: a request decoded on a recycled slot gets
    the same tokens as on a fresh engine (recurrent arch — stale conv/h
    state would corrupt it)."""
    cfg, _, params = _setup("falcon-mamba-7b")
    probe = _prompts(1, cfg.vocab_size)[0]
    fresh, _, _ = _run(ServeEngine, cfg, ServeConfig(max_batch=1, max_seq=48),
                       params, [probe])
    # same single slot serves two other requests first, then the probe
    others = _prompts(2, cfg.vocab_size)
    reused, _, _ = _run(ServeEngine, cfg, ServeConfig(max_batch=1, max_seq=48),
                        params, others + [probe])
    np.testing.assert_array_equal(fresh[0], reused[2])


@pytest.mark.parametrize("arch",
                         ["llama3.2-1b", "recurrentgemma-2b", "whisper-small"])
def test_mixed_position_vector_decode_matches_scalar(arch):
    """Model-level decode contract: one batched call at per-row positions
    [p0, p1] is bitwise equal to two batch-1 scalar-pos calls — including
    the rolling-window cache (recurrentgemma) and learned positions +
    cross-attention (whisper)."""
    cfg, model, params = _setup(arch, max_seq=32)
    S = 32
    t0 = [9, 5]  # ragged prompt lengths -> genuinely mixed positions
    toks = RNG.integers(0, cfg.vocab_size, size=(2, 20)).astype(np.int32)
    caches, logits0 = [], []
    for b in range(2):
        batch = {"tokens": jnp.asarray(toks[b : b + 1, : t0[b]])}
        if cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                jax.random.PRNGKey(b), (1, cfg.encoder.n_frames, cfg.d_model),
                jnp.float32)
        lg, c = model.prefill(params, batch, cfg, model.init_cache(cfg, 1, S))
        caches.append(c)
        logits0.append(lg)
    stacked = jax.tree.map(lambda a, b: jnp.concatenate([a, b], axis=1),
                           caches[0], caches[1])
    pos = np.array(t0, np.int32)
    last = np.array([int(jnp.argmax(logits0[b][0])) for b in range(2)],
                    np.int32)
    # 8 joint decode steps at mixed positions (recurrentgemma: crosses its
    # window=16 rolling-buffer wraparound) vs per-row scalar decode
    refs = [(caches[b], int(last[b])) for b in range(2)]
    for _ in range(8):
        lg, stacked = model.decode_step(
            params, jnp.asarray(last[:, None]), stacked,
            jnp.asarray(pos), cfg)
        for b in range(2):
            c_b, tok_b = refs[b]
            lg_b, c_b = model.decode_step(
                params, jnp.asarray([[tok_b]], jnp.int32), c_b,
                int(pos[b]), cfg)
            np.testing.assert_array_equal(
                np.asarray(lg[b]), np.asarray(lg_b[0]),
                err_msg=f"{arch} pos={pos.tolist()} row={b}")
            refs[b] = (c_b, int(jnp.argmax(lg_b[0])))
        last = np.array([int(jnp.argmax(lg[b])) for b in range(2)], np.int32)
        pos += 1
