"""Fused-codec kernel layer: property tests against the int64 oracle, the
autotuner cache contract, and the fused serving/training routes.

The fused kernels (entangle -> op -> extract in one pallas_call) must be
bit-identical to running the codec as separate passes, for every plan temp
mode (int32 single-word AND the dualword path of core/wideint.py), for
failure-free extraction and for every failed-stream index r — on ragged,
non-block-multiple shapes (ops.py pads/unpads).
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.entangle import disentangle_oracle_np
from repro.core.plan import make_plan
from repro.kernels import autotune, ops, ref

SET = settings(max_examples=8, deadline=None)

# (M, w, temp): spans the int32 single-word temp and the dualword temp
PLANS = [(3, 16, None), (4, 32, None), (3, 32, "dualword"), (8, 32, None)]


def _entangled_delta_np(d: np.ndarray, l: int) -> np.ndarray:
    return ((np.roll(d, 1, 0) << l) + d).astype(np.int32)


@st.composite
def matmul_case(draw):
    M, w, temp = draw(st.sampled_from(PLANS))
    plan = make_plan(M, w, temp=temp)
    B = draw(st.integers(3, 33))
    K = draw(st.integers(3, 40))
    N = draw(st.integers(3, 65))
    seed = draw(st.integers(0, 2**31 - 1))
    return plan, B, K, N, seed


@given(matmul_case())
@SET
def test_fused_matmul_matches_oracle_all_failures(case):
    plan, B, K, N, seed = case
    rng = np.random.default_rng(seed)
    lim = max(int(np.sqrt(plan.max_output_magnitude / K)) // 2, 1)
    lim = min(lim, 15)
    c = jnp.asarray(rng.integers(-lim, lim + 1, size=(plan.M, B, K)).astype(np.int32))
    g = jnp.asarray(rng.integers(-lim, lim + 1, size=(K, N)).astype(np.int32))

    delta = ops.entangled_matmul(c, g, plan, bb=16, bn=32, bk=32)
    np.testing.assert_array_equal(
        np.asarray(delta), np.asarray(ref.entangled_matmul_ref(c, g, plan.l)))

    true = np.einsum("mbk,kn->mbn", np.asarray(c, np.int64),
                     np.asarray(g, np.int64))
    for r in [None] + list(range(plan.M)):
        fused = ops.entangled_matmul(
            c, g, plan, fuse_epilogue=True, failed=r, bb=16, bn=32, bk=32)
        # fused epilogue == the numpy int64 oracle on the entangled product
        oracle = disentangle_oracle_np(np.asarray(delta), plan,
                                       0 if r is None else r)
        np.testing.assert_array_equal(np.asarray(fused), oracle)
        np.testing.assert_array_equal(np.asarray(fused), true)


@st.composite
def grouped_case(draw):
    M, w, temp = draw(st.sampled_from(PLANS))
    plan = make_plan(M, w, temp=temp)
    E = draw(st.integers(1, 5))
    Cg = draw(st.integers(2, 17))
    K = draw(st.integers(3, 33))
    N = draw(st.integers(3, 40))
    seed = draw(st.integers(0, 2**31 - 1))
    return plan, E, Cg, K, N, seed


@given(grouped_case())
@SET
def test_fused_grouped_matmul_matches_oracle_all_failures(case):
    """The grouped (per-expert) kernel: entangled products, fused
    extraction and every failed-stream index must match the jnp oracle
    and the numpy int64 disentangle — per expert, bit-exactly."""
    plan, E, Cg, K, N, seed = case
    rng = np.random.default_rng(seed)
    lim = max(int(np.sqrt(plan.max_output_magnitude / K)) // 2, 1)
    lim = min(lim, 15)
    c = jnp.asarray(rng.integers(
        -lim, lim + 1, size=(plan.M, E, Cg, K)).astype(np.int32))
    g = jnp.asarray(rng.integers(
        -lim, lim + 1, size=(E, K, N)).astype(np.int32))

    delta = ops.entangled_matmul_grouped(c, g, plan, bb=16, bn=32, bk=32)
    np.testing.assert_array_equal(
        np.asarray(delta),
        np.asarray(ref.entangled_matmul_grouped_ref(c, g, plan.l)))

    true = np.einsum("meck,ekn->mecn", np.asarray(c, np.int64),
                     np.asarray(g, np.int64))
    for r in [None] + list(range(plan.M)):
        fused = ops.entangled_matmul_grouped(
            c, g, plan, fuse_epilogue=True, failed=r, bb=16, bn=32, bk=32)
        oracle = disentangle_oracle_np(
            np.asarray(delta).reshape(plan.M, -1), plan,
            0 if r is None else r)
        np.testing.assert_array_equal(
            np.asarray(fused).reshape(plan.M, -1), oracle)
        np.testing.assert_array_equal(np.asarray(fused), true)


@st.composite
def conv_case(draw):
    M, w, temp = draw(st.sampled_from(PLANS))
    plan = make_plan(M, w, temp=temp)
    B = draw(st.integers(1, 3))
    D = draw(st.integers(3, 40))
    T = draw(st.integers(5, 70))
    kf = draw(st.integers(1, 4))
    seed = draw(st.integers(0, 2**31 - 1))
    return plan, B, D, T, kf, seed


@given(conv_case())
@SET
def test_fused_conv1d_matches_oracle_all_failures(case):
    plan, B, D, T, kf, seed = case
    rng = np.random.default_rng(seed)
    lim = max(int(np.sqrt(plan.max_output_magnitude / kf)) // 2, 1)
    lim = min(lim, 15)
    x = jnp.asarray(
        rng.integers(-lim, lim + 1, size=(plan.M, B, D, T)).astype(np.int32))
    w = jnp.asarray(rng.integers(-lim, lim + 1, size=(D, kf)).astype(np.int32))

    delta = ops.entangled_conv1d(x, w, plan, bd=16, bt=32)
    np.testing.assert_array_equal(
        np.asarray(delta), np.asarray(ref.entangled_conv1d_ref(x, w, plan.l)))

    for r in [None] + list(range(plan.M)):
        fused = ops.entangled_conv1d(
            x, w, plan, fuse_epilogue=True, failed=r, bd=16, bt=32)
        flat = np.asarray(delta).reshape(plan.M, -1)
        oracle = disentangle_oracle_np(flat, plan, 0 if r is None else r)
        np.testing.assert_array_equal(
            np.asarray(fused).reshape(plan.M, -1), oracle)


def test_fused_equals_separate_three_pass():
    """One fused pallas_call == entangle -> GEMM -> disentangle passes."""
    plan = make_plan(4, 32)
    rng = np.random.default_rng(7)
    c = jnp.asarray(rng.integers(-15, 16, size=(4, 24, 48)).astype(np.int32))
    g = jnp.asarray(rng.integers(-15, 16, size=(48, 40)).astype(np.int32))
    fused = ops.entangled_matmul(c, g, plan, fuse_epilogue=True,
                                 bb=16, bn=32, bk=16)
    delta = ops.entangled_matmul(c, g, plan, bb=16, bn=32, bk=16)
    separate = ops.disentangle(delta, plan)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(separate))


# ---------------------------------------------------------------- autotune --

def test_autotune_cache_hit_and_persistence(tmp_path):
    path = tmp_path / "autotune.json"
    cache = autotune.reset_cache(str(path))
    try:
        rng = np.random.default_rng(3)
        plan = make_plan(4, 32)
        c = jnp.asarray(rng.integers(-15, 16, size=(4, 16, 32)).astype(np.int32))
        g = jnp.asarray(rng.integers(-15, 16, size=(32, 16)).astype(np.int32))

        out1 = ops.entangled_matmul(c, g, plan, fuse_epilogue=True,
                                    blocks="auto")
        assert cache.sweeps == 1 and cache.hits == 0
        out2 = ops.entangled_matmul(c, g, plan, fuse_epilogue=True,
                                    blocks="auto")
        assert cache.sweeps == 1 and cache.hits == 1  # in-process hit
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
        # tuned blocks don't change numerics vs the oracle
        true = np.einsum("mbk,kn->mbn", np.asarray(c, np.int64),
                         np.asarray(g, np.int64))
        np.testing.assert_array_equal(np.asarray(out1), true)

        # a fresh process (fresh in-proc dict) hits the JSON file instead
        cache2 = autotune.reset_cache(str(path))
        out3 = ops.entangled_matmul(c, g, plan, fuse_epilogue=True,
                                    blocks="auto")
        assert cache2.sweeps == 0 and cache2.hits == 1
        np.testing.assert_array_equal(np.asarray(out1), np.asarray(out3))

        # a different shape is a different key -> new sweep
        c2 = jnp.asarray(rng.integers(-15, 16, size=(4, 16, 64)).astype(np.int32))
        g2 = jnp.asarray(rng.integers(-15, 16, size=(64, 16)).astype(np.int32))
        ops.entangled_matmul(c2, g2, plan, fuse_epilogue=True, blocks="auto")
        assert cache2.sweeps == 1
        assert path.exists() and "entangled_matmul" in path.read_text()
    finally:
        autotune.reset_cache(None)  # don't leak the tmp cache to other tests


def test_explicit_blocks_dict_overrides_defaults():
    plan = make_plan(4, 32)
    rng = np.random.default_rng(5)
    c = jnp.asarray(rng.integers(-15, 16, size=(4, 8, 16)).astype(np.int32))
    g = jnp.asarray(rng.integers(-15, 16, size=(16, 8)).astype(np.int32))
    a = ops.entangled_matmul(c, g, plan, fuse_epilogue=True,
                             blocks={"bb": 8, "bn": 8, "bk": 8})
    b = ops.entangled_matmul(c, g, plan, fuse_epilogue=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError):
        ops.entangled_matmul(c, g, plan, blocks="nope")


# ------------------------------------------------------- fused route users --

def test_ft_logits_fused_equals_separate_pass():
    from repro.ft.heads import ft_logits, quantize_head

    rng = np.random.default_rng(11)
    h = jnp.asarray(rng.normal(size=(8, 32)).astype(np.float32))
    head = jnp.asarray(rng.normal(size=(32, 48)).astype(np.float32))
    hq, ws = quantize_head(head)
    base = ft_logits(h, hq, ws, M=4, fuse_epilogue=False)
    for fg in [None, 0, 2]:
        fused = ft_logits(h, hq, ws, M=4, failed_group=fg, fuse_epilogue=True)
        sep = ft_logits(h, hq, ws, M=4, failed_group=fg, fuse_epilogue=False)
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(sep))
        np.testing.assert_array_equal(np.asarray(fused), np.asarray(base))


def test_ft_grad_sync_pallas_codec_matches_xla():
    from repro.dist.collectives import ft_grad_sync

    rng = np.random.default_rng(13)
    g = {"a": jnp.asarray(rng.normal(size=(700,)).astype(np.float32)),
         "b": jnp.asarray(rng.normal(size=(13, 9)).astype(np.float32))}
    for fb in [None, 1, 3]:
        x, dx = ft_grad_sync(g, axis_name=None, n_replicas=1, M=4,
                             failed_block=fb, codec="xla")
        p, dp = ft_grad_sync(g, axis_name=None, n_replicas=1, M=4,
                             failed_block=fb, codec="pallas")
        for k in g:
            np.testing.assert_array_equal(np.asarray(x[k]), np.asarray(p[k]))
