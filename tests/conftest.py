"""Test-session setup: dependency gates.

The image does not ship ``hypothesis`` and installing packages is forbidden,
so the property tests run against :mod:`tests._mini_hypothesis` (a seeded
random sweep with the same decorator surface). When the real package exists
it wins — the shim is only registered on ImportError.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

try:  # pragma: no cover - environment-dependent
    import hypothesis  # noqa: F401
except ImportError:
    import _mini_hypothesis

    sys.modules["hypothesis"] = _mini_hypothesis
    sys.modules["hypothesis.strategies"] = _mini_hypothesis.strategies
