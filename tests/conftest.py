"""Test-session setup: dependency gates + per-module JAX cache reclaim.

The image does not ship ``hypothesis`` and installing packages is forbidden,
so the property tests run against :mod:`tests._mini_hypothesis` (a seeded
random sweep with the same decorator surface). When the real package exists
it wins — the shim is only registered on ImportError.
"""
import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))


@pytest.fixture(autouse=True, scope="module")
def _reclaim_jax_caches():
    """Drop JAX's global compiled-executable caches after every module.

    XLA:CPU JIT-compiles each distinct program into fresh executable
    pages, and jax's process-global executable cache (pxla's weakref LRU)
    keeps every one alive — across the full suite the process accumulates
    tens of thousands of mmap regions and SEGFAULTS inside
    ``backend_compile`` when it crosses ``vm.max_map_count`` (65530
    default; observed ~40 min in). Nothing is shared across test modules
    (each builds its own engines/params, and jit closures are per-object
    anyway), so clearing at module teardown bounds the map count at the
    cost of re-compiling a handful of library-level helpers per module.
    """
    yield
    import jax

    jax.clear_caches()

try:  # pragma: no cover - environment-dependent
    import hypothesis  # noqa: F401
except ImportError:
    import _mini_hypothesis

    sys.modules["hypothesis"] = _mini_hypothesis
    sys.modules["hypothesis.strategies"] = _mini_hypothesis.strategies
