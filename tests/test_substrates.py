"""Substrate tests: FT collectives, checkpointing (atomic/verified/elastic),
data pipeline, straggler deadline, serving engine, entangled logits."""
import dataclasses
import pathlib
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import Prefetcher, TokenShardStore
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.dist.collectives import checksum_grad_sync, ft_grad_sync
from repro.models import get_model
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.ft.heads import ft_logits, quantize_head
from repro.train.checkpoint import CheckpointManager
from repro.train.straggler import DeadlineExecutor
from repro.train.train_step import TrainConfig, init_state, make_train_step

RNG = np.random.default_rng(11)


# ------------------------------------------------------------- collectives --

def _grads():
    return {
        "a": jnp.asarray(RNG.normal(size=(1000,)).astype(np.float32)),
        "b": jnp.asarray(RNG.normal(size=(37, 5)).astype(np.float32)),
    }


def test_ft_grad_sync_exact_recovery():
    g = _grads()
    clean, _ = ft_grad_sync(g, axis_name=None, n_replicas=1, M=4)
    for fb in range(4):
        rec, diag = ft_grad_sync(g, axis_name=None, n_replicas=1, M=4,
                                 failed_block=fb)
        for k in g:
            np.testing.assert_array_equal(np.asarray(clean[k]), np.asarray(rec[k]))
        assert diag["ne_failed"] == fb


def test_ft_grad_sync_quantization_error_bounded():
    g = _grads()
    rec, _ = ft_grad_sync(g, axis_name=None, n_replicas=8, M=4)
    for k in g:
        err = float(jnp.abs(rec[k] * 8 - g[k]).max())  # mean divides by R
        assert err < 1e-4


def test_checksum_grad_sync_recovery():
    g = _grads()
    clean, _ = checksum_grad_sync(g, axis_name=None, n_replicas=1, M=4)
    for fb in range(4):
        rec, _ = checksum_grad_sync(g, axis_name=None, n_replicas=1, M=4,
                                    failed_block=fb)
        for k in g:
            np.testing.assert_allclose(
                np.asarray(clean[k]), np.asarray(rec[k]), atol=1e-6)


def test_ft_train_step_loss_unaffected_by_failstop():
    """A fail-stopped gradient block must not change the training step at
    all — the paper's roll-forward guarantee at trainer level."""
    cfg = get_smoke_config("llama3.2-1b")
    tcfg = TrainConfig(max_seq=64, grad_sync="entangle")
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                          cfg.vocab_size)}
    s_clean, m_clean = jax.jit(make_train_step(cfg, tcfg))(state, batch)
    s_fail, m_fail = jax.jit(make_train_step(cfg, tcfg, failed_block=2))(state, batch)
    for a, b in zip(jax.tree.leaves(s_clean["params"]),
                    jax.tree.leaves(s_fail["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- checkpoint --

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.arange(10.0), "n": {"m": jnp.ones((3, 3))},
             "step": jnp.int32(5)}
    for s in (1, 2, 3):
        mgr.save(state, s, blocking=True)
    assert mgr.all_steps() == [2, 3]
    restored, step = mgr.restore(state)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(10.0))


def test_checkpoint_detects_corruption(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save({"w": jnp.arange(4.0)}, 1, blocking=True)
    victim = next((tmp_path / "step_00000001").glob("leaf_*.npy"))
    raw = bytearray(victim.read_bytes())
    raw[-1] ^= 0xFF
    victim.write_bytes(bytes(raw))
    with pytest.raises(IOError, match="corruption"):
        mgr.restore({"w": jnp.arange(4.0)})


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore with explicit shardings (the elastic path; 1-device here)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(state, 1, blocking=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = mgr.restore(state, shardings=sh)
    assert restored["w"].sharding == sh["w"]


# -------------------------------------------------------------------- data --

def test_synthetic_deterministic_and_learnable_structure():
    d = SyntheticLM(DataConfig(vocab_size=100, seq_len=16, batch_size=2))
    b1, b2 = d.batch(3), d.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(d.batch(4)["tokens"], b1["tokens"])


def test_token_shard_store_single_loss_recovery(tmp_path):
    store = TokenShardStore(str(tmp_path), M=4)
    toks = RNG.integers(0, 65000, size=(5, 331)).astype(np.int32)
    paths = store.write_group("g", toks)
    for lost in range(4):
        store2 = TokenShardStore(str(tmp_path), M=4)
        backup = paths[lost].read_bytes()
        paths[lost].unlink()
        np.testing.assert_array_equal(store2.read_group("g"), toks)
        paths[lost].write_bytes(backup)
    # double loss must raise, not silently corrupt
    paths[0].unlink(); paths[1].unlink()
    with pytest.raises(IOError, match="single-failure"):
        store.read_group("g")


def test_prefetcher_order():
    out = list(Prefetcher(iter(range(7)), depth=2))
    assert out == list(range(7))


# --------------------------------------------------------------- straggler --

def test_deadline_executor_marks_straggler():
    def fast():
        return 1

    def slow():
        time.sleep(1.5)
        return 2

    ex = DeadlineExecutor(deadline_s=0.3)
    res = ex.run([fast, slow, fast])
    assert DeadlineExecutor.failed_index(res) == 1
    assert res[0].value == 1 and res[2].value == 1 and res[1].failed


# ------------------------------------------------------------------- serve --

def test_serve_engine_completes_requests():
    cfg = get_smoke_config("llama3.2-1b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg, max_seq=64)
    eng = ServeEngine(cfg, ServeConfig(max_batch=2, max_seq=64), params)
    for r in range(3):
        eng.submit(Request(rid=r, prompt=RNG.integers(
            0, cfg.vocab_size, size=5).astype(np.int32), max_new=4))
    done = eng.run_to_completion()
    assert len(done) == 3
    assert all(len(r.out) == 4 for r in done)


def test_ft_logits_failure_exact_and_faithful():
    B, D, V = 8, 64, 128
    h = jnp.asarray(RNG.normal(size=(B, D)).astype(np.float32))
    head = jnp.asarray(RNG.normal(size=(D, V)).astype(np.float32))
    hq, ws = quantize_head(head)
    base = ft_logits(h, hq, ws, M=4)
    for fg in range(4):
        np.testing.assert_array_equal(
            np.asarray(base), np.asarray(ft_logits(h, hq, ws, M=4,
                                                   failed_group=fg)))
    ref = np.asarray(h @ head)
    agree = (np.argmax(np.asarray(base), 1) == np.argmax(ref, 1)).mean()
    assert agree >= 0.9
