"""Per-kernel shape/dtype sweeps, exact-equality vs the pure-jnp oracles
(interpret=True executes kernel bodies on CPU — the task-mandated mode)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.plan import make_plan
from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


@pytest.mark.parametrize("M,w", [(3, 32), (4, 32), (8, 32), (4, 16)])
@pytest.mark.parametrize("n", [64, 1000, 1024, 4097])
def test_entangle_kernel_sweep(M, w, n):
    plan = make_plan(M, w)
    lim = min(plan.max_output_magnitude, 2**20) or 100
    c = jnp.asarray(RNG.integers(-lim, lim, size=(M, n)).astype(np.int32))
    out = ops.entangle(c, plan)
    expect = ref.entangle_ref(c, plan.l)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("M,w", [(3, 32), (4, 32), (8, 32), (4, 16)])
@pytest.mark.parametrize("failed", [None, 0, 1, -1])
def test_disentangle_kernel_sweep(M, w, failed):
    plan = make_plan(M, w)
    D = plan.max_output_magnitude
    d = RNG.integers(-D, D + 1, size=(M, 2048)).astype(np.int64)
    delta = jnp.asarray(((np.roll(d, 1, 0) << plan.l) + d).astype(np.int32))
    f = (failed % M) if failed is not None else None
    out = ops.disentangle(delta, plan, failed=f)
    np.testing.assert_array_equal(np.asarray(out), d)
    expect = ref.disentangle_ref(delta, plan, r=f or 0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("shape", [(4, 8, 32), (3, 128, 128), (4, 130, 300)])
@pytest.mark.parametrize("n_out", [16, 128, 257])
def test_entangled_matmul_sweep(shape, n_out):
    plan = make_plan(shape[0], 32)
    c = jnp.asarray(RNG.integers(-15, 15, size=shape).astype(np.int32))
    g = jnp.asarray(RNG.integers(-15, 15, size=(shape[2], n_out)).astype(np.int32))
    out = ops.entangled_matmul(c, g, plan, bb=32, bn=64, bk=32)
    expect = ref.entangled_matmul_ref(c, g, plan.l)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
    # and the entangled product disentangles to the true integer GEMM
    true = np.einsum("mbk,kn->mbn", np.asarray(c, np.int64), np.asarray(g, np.int64))
    rec = ops.disentangle(out, plan, failed=shape[0] - 1)
    np.testing.assert_array_equal(np.asarray(rec), true)


@pytest.mark.parametrize("B,D,T,kf", [(1, 16, 64, 4), (2, 130, 513, 4), (1, 64, 128, 3)])
def test_conv1d_kernel_sweep(B, D, T, kf):
    x = jnp.asarray(RNG.integers(-30, 30, size=(B, D, T)).astype(np.int32))
    w = jnp.asarray(RNG.integers(-10, 10, size=(D, kf)).astype(np.int32))
    out = ops.conv1d_causal(x, w, bd=16, bt=64)
    expect = ref.conv1d_causal_ref(x, w)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("M,n", [(3, 100), (8, 4096)])
def test_checksum_kernel(M, n):
    c = jnp.asarray(RNG.integers(-1000, 1000, size=(M, n)).astype(np.int32))
    out = ops.checksum(c)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref.checksum_ref(c))[0])


def test_entangle_kernel_nd_shapes():
    """ops wrappers flatten arbitrary trailing shapes."""
    plan = make_plan(4, 32)
    c = jnp.asarray(RNG.integers(-100, 100, size=(4, 3, 5, 7)).astype(np.int32))
    out = ops.entangle(c, plan)
    assert out.shape == c.shape
    rec = ops.disentangle(ref_delta(c, plan), plan, failed=2)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(c))


def ref_delta(c, plan):
    d = np.asarray(c, dtype=np.int64)
    return jnp.asarray(((np.roll(d, 1, 0) << plan.l) + d).astype(np.int32))
