"""Serving-correctness invariant: prefill + step-by-step decode produces the
SAME logits as the training forward pass, for every architecture family
(dropless MoE capacity for exactness)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import get_model

KEY = jax.random.PRNGKey(3)
B, T, T0 = 2, 24, 16


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_train(arch):
    cfg = get_smoke_config(arch)
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=1e9))
    model = get_model(cfg)
    params = model.init(KEY, cfg, max_seq=64)
    tokens = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tokens}
    n_prefix = 0
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            KEY, (B, cfg.vision.n_patches, cfg.d_model), jnp.float32)
        n_prefix = cfg.vision.n_patches
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.encoder.n_frames, cfg.d_model), jnp.float32)

    full = model.forward_train(params, batch, cfg)
    full = full[0] if isinstance(full, tuple) else full

    cache = model.init_cache(cfg, B, max_seq=64)
    pre = dict(batch)
    pre["tokens"] = tokens[:, :T0]
    logits, cache = model.prefill(params, pre, cfg, cache)
    errs = [float(np.abs(np.asarray(logits) - np.asarray(full[:, T0 - 1])).max())]
    for t in range(T0, T):
        logits, cache = model.decode_step(
            params, tokens[:, t : t + 1], cache, n_prefix + t, cfg)
        errs.append(float(np.abs(np.asarray(logits) - np.asarray(full[:, t])).max()))
    assert max(errs) < 0.05, (arch, errs)
