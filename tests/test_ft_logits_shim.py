"""repro.serve.ft_logits deprecation shim: warns on import, keeps the
exact public surface working (signatures AND behavior) until every caller
has migrated to repro.ft.heads."""
import importlib
import inspect
import sys
import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.plan import make_plan


def _fresh_import():
    sys.modules.pop("repro.serve.ft_logits", None)
    return importlib.import_module("repro.serve.ft_logits")


def test_import_emits_deprecation_warning():
    with pytest.warns(DeprecationWarning, match="repro.ft.heads"):
        _fresh_import()


def test_public_surface_locked():
    """The shim must keep every legacy name with its exact signature —
    a rename or dropped kwarg would break pinned callers silently."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        shim = _fresh_import()

    want = {
        "ft_logits": ["h", "head_q", "w_scale", "M", "plan", "failed_group",
                      "use_pallas", "fuse_epilogue", "blocks"],
        "ft_logits_decode": ["h", "head_q", "w_scale", "plan",
                             "failed_group", "use_pallas", "fuse_epilogue",
                             "blocks"],
        "ft_logits_prefill": ["h", "head_q", "w_scale", "plan",
                              "failed_group", "use_pallas", "fuse_epilogue",
                              "blocks"],
        "decode_group_order": ["B", "M"],
        "quantize_head": ["w"],
    }
    for name, params in want.items():
        fn = getattr(shim, name)
        assert list(inspect.signature(fn).parameters) == params, name
    assert set(shim.__all__) == set(want)


def test_shim_behavior_matches_subsystem():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        shim = _fresh_import()
    from repro.ft import heads

    rng = np.random.default_rng(5)
    h = jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(16, 32)).astype(np.float32))
    head_q, w_scale = shim.quantize_head(w)
    plan = make_plan(4, 32)
    old = shim.ft_logits_decode(h, head_q, w_scale, plan=plan,
                                failed_group=2)
    new = heads.ft_logits_decode(h, head_q, w_scale, plan=plan,
                                 failed_group=2)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))
