"""The ``repro.serve.ft_logits`` deprecation shim is REMOVED (it warned
since the entangled-ops v2 redesign): importing it must fail, and
``repro.ft.heads`` is the ONLY surface defining the head entries — the
``repro.serve`` package re-exports ARE the subsystem functions, not
copies, so there is exactly one implementation to patch or pin."""
import importlib
import inspect

import pytest


def test_shim_module_is_gone():
    with pytest.raises(ModuleNotFoundError):
        importlib.import_module("repro.serve.ft_logits")


def test_heads_is_the_only_surface():
    """The serve package's convenience names must be the repro.ft.heads
    objects THEMSELVES (identity, not wrappers): one surface, one
    signature, one place the protected head projection lives."""
    import repro.serve as serve
    from repro.ft import heads

    for name in ("ft_logits", "ft_logits_decode", "ft_logits_prefill",
                 "quantize_head"):
        assert getattr(serve, name) is getattr(heads, name), name


def test_heads_surface_locked():
    """The subsystem keeps the legacy signatures — a rename or dropped
    kwarg would break callers pinned on the old shim's contract."""
    from repro.ft import heads

    want = {
        "ft_logits": ["h", "head_q", "w_scale", "M", "plan", "failed_group",
                      "use_pallas", "fuse_epilogue", "blocks"],
        "ft_logits_decode": ["h", "head_q", "w_scale", "plan",
                             "failed_group", "use_pallas", "fuse_epilogue",
                             "blocks"],
        "ft_logits_prefill": ["h", "head_q", "w_scale", "plan",
                              "failed_group", "use_pallas", "fuse_epilogue",
                              "blocks"],
        "decode_group_order": ["B", "M"],
        "quantize_head": ["w"],
    }
    for name, params in want.items():
        fn = getattr(heads, name)
        assert list(inspect.signature(fn).parameters) == params, name
