"""Entangled-ops v2 invariants: ahead-of-time ProtectionPlans, the startup
weight-quantization hoist, and the grouped (MoE per-expert) protected GEMM.

  * protected_matmul_grouped recovery is EXACT for every failed group on
    the fused kernel, the unfused kernel and the XLA reference path —
    including per-expert row counts that do not divide into M groups;
  * the grouped integer path is faithful to the float per-expert einsum
    within quantization tolerance, and pre-quantized (startup) weights
    produce bit-identical results to in-graph quantization;
  * prepare_params installs q8 entries for exactly the in-scope sites
    (per-layer / per-expert scales, float masters untouched, MTP skipped)
    and a traced decode/prefill step after startup performs ZERO weight
    quantizations (the hoist contract, via quantize.TRACE_STATS);
  * compile_plans freezes the census into an immutable lookup the
    FTContext resolves from; a census gap degrades to a lazy registry
    entry with a RuntimeWarning instead of crashing.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.plan import make_plan
from repro.ft import (CompiledPlans, FTContext, PlanRegistry, compile_plans,
                      prepare_params, protected_matmul_grouped,
                      quantize_weight_stacked)
from repro.ft import quantize as ftq

RNG = np.random.default_rng(41)


def _xw(L=2, E=3, C=6, K=16, N=12):
    x = jnp.asarray(RNG.normal(size=(L, E, C, K)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(E, K, N)).astype(np.float32))
    return x, w


@pytest.mark.parametrize("use_pallas,fuse", [(True, True), (True, False),
                                             (False, False)])
@pytest.mark.parametrize("C", [8, 6])  # 2*6=12 divides M=4; 2*6 rows -> pad 0
def test_protected_matmul_grouped_failstop_exact(use_pallas, fuse, C):
    plan = make_plan(4, 32)
    x, w = _xw(C=C)
    healthy = protected_matmul_grouped(x, w, plan=plan,
                                       use_pallas=use_pallas,
                                       fuse_epilogue=fuse)
    assert healthy.shape == (2, 3, C, 12)
    for r in range(plan.M):
        injected = protected_matmul_grouped(
            x, w, plan=plan, failed_group=r, use_pallas=use_pallas,
            fuse_epilogue=fuse)
        np.testing.assert_array_equal(np.asarray(healthy),
                                      np.asarray(injected),
                                      err_msg=f"failed_group={r}")


def test_protected_matmul_grouped_ragged_pad_exact():
    """Per-expert rows (L*C = 2*5 = 10) that do NOT divide into M=4 groups:
    the zero-row padding must be invisible in the recovered outputs."""
    plan = make_plan(4, 32)
    x, w = _xw(C=5)
    healthy = protected_matmul_grouped(x, w, plan=plan)
    for r in range(plan.M):
        injected = protected_matmul_grouped(x, w, plan=plan, failed_group=r)
        np.testing.assert_array_equal(np.asarray(healthy),
                                      np.asarray(injected))


def test_protected_matmul_grouped_faithful_and_prequantized():
    plan = make_plan(4, 32)
    x, w = _xw()
    got = np.asarray(protected_matmul_grouped(x, w, plan=plan))
    ref = np.einsum("leck,ekn->lecn", np.asarray(x), np.asarray(w))
    # per-expert int8 grids: comparable tolerance to the plain path
    assert np.max(np.abs(got - ref)) < 0.15 * np.max(np.abs(ref))
    # startup-prequantized weights are bit-identical to in-graph quantization
    q8 = quantize_weight_stacked(w)
    got_pre = np.asarray(protected_matmul_grouped(
        x, (q8["w"], q8["scale"]), plan=plan, failed_group=1))
    np.testing.assert_array_equal(got, got_pre)


def test_quantize_weight_stacked_per_matrix_grids():
    w = jnp.asarray(RNG.normal(size=(3, 2, 8, 5)).astype(np.float32))
    q8 = quantize_weight_stacked(w)
    assert q8["w"].shape == (3, 2, 8, 5) and q8["w"].dtype == jnp.int32
    assert q8["scale"].shape == (3, 2)
    # each matrix saturates its own grid at 127
    assert int(jnp.max(jnp.abs(q8["w"][0, 0]))) == 127
    assert int(jnp.max(jnp.abs(q8["w"][2, 1]))) == 127


# ---------------------------------------------------------------------------
# prepare_params / compile_plans / trace-count — engine-level contracts
# ---------------------------------------------------------------------------

def _engine(arch, **kw):
    from repro.configs import get_smoke_config
    from repro.models import get_model
    from repro.serve import ServeConfig, ServeEngine

    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg, max_seq=48)
    scfg = ServeConfig(max_batch=4, max_seq=48, ft_mode="entangle", ft_M=4,
                       **kw)
    return cfg, params, ServeEngine(cfg, scfg, params)


def test_prepare_params_scoped_q8_entries():
    from repro.configs import get_smoke_config
    from repro.models import get_model

    cfg = get_smoke_config("deepseek-v2-lite-16b")
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg, max_seq=48)

    qkv_only = prepare_params(params, scope="qkv")
    unit = qkv_only["stack"][1][0]  # the scanned attn_moe block params
    assert "q8" in unit["attn"]["wkv_a"] and "q8" not in unit["attn"]["wo"]
    assert "we_gate_q8" not in unit["moe"]
    assert "router_q8" not in unit["moe"]

    allp = prepare_params(params, scope="all")
    unit_all = allp["stack"][1][0]
    moe_all = unit_all["moe"]
    assert "q8" in unit_all["attn"]["wo"], \
        "scope=all must cover output projections"
    for name in ("we_gate", "we_up", "we_down", "router"):
        assert name + "_q8" in moe_all, name
        # per-layer (and per-expert) scales follow the stacked leading dims;
        # the q8 copy is PACKED 4 int8 lanes per int32 word along K
        w = moe_all[name]
        packed_k = -(-w.shape[-2] // 4)
        assert moe_all[name + "_q8"]["w"].shape == \
            (*w.shape[:-2], packed_k, w.shape[-1])
        assert moe_all[name + "_q8"]["scale"].shape == w.shape[:-2]
        np.testing.assert_array_equal(  # float master untouched
            np.asarray(w), np.asarray(params["stack"][1][0]["moe"][name]))


def test_prepare_params_skips_mtp():
    from repro.configs import get_smoke_config
    from repro.models import get_model

    cfg = get_smoke_config("deepseek-v3-671b")  # has the MTP head
    params = get_model(cfg).init(jax.random.PRNGKey(0), cfg, max_seq=32)
    assert "mtp" in params
    prepared = prepare_params(params, scope="all")
    flat = jax.tree_util.tree_flatten_with_path(prepared["mtp"])[0]
    assert not any("q8" in jax.tree_util.keystr(p) for p, _ in flat), \
        "train-only MTP weights must not be duplicated into q8 copies"


def test_compiled_plans_lookup_and_gap_fallback():
    reg = PlanRegistry(make_plan(4, 32))
    e1 = reg.entry("qkv.q", rows=4, K=64, N=48, backend="interpret_cpu")
    e2 = reg.entry("moe.gate", rows=8, K=64, N=32, backend="interpret_cpu",
                   groups=8)
    plans = compile_plans(reg)
    assert isinstance(plans, CompiledPlans) and len(plans) == 2
    assert plans.lookup("qkv.q", e1.shape) is e1
    assert plans.lookup("moe.gate", e2.shape) is e2
    assert e2.grouped and e2.shape == (4, 8, 2, 64, 32)
    assert plans.categories() == {"qkv", "moe"}

    # census filter: freeze a subset
    sub = compile_plans(reg, {("qkv.q", e1.shape): e1.blocks})
    assert len(sub) == 1 and sub.lookup("moe.gate", e2.shape) is None

    # a census gap warns and degrades to a lazy entry — never crashes
    ctx = FTContext(registry=reg, scope="all", plans=sub, use_pallas=False)
    x = jnp.asarray(RNG.normal(size=(4, 32)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(32, 16)).astype(np.float32))
    with pytest.warns(RuntimeWarning, match="census gap"):
        y = ctx.matmul("qkv.k", x, w)
    assert y.shape == (4, 16)
    assert reg.get("qkv.k", (4, 1, 32, 16), "interpret_cpu") is not None


@pytest.mark.parametrize("arch", ["llama3.2-1b", "deepseek-v2-lite-16b"])
def test_no_weight_quantization_in_traced_steps(arch):
    """THE hoist contract: with plans compiled at startup, tracing and
    running decode steps and chunked prefill admissions — including the
    per-failed-group retraces — performs zero eq.-13 weight quantizations.
    (quantize_weight is a Python-level call, so any in-graph use would
    bump the counter at trace time.)"""
    from repro.serve import Request

    cfg, params, eng = _engine(arch, ft_scope="all", prefill_chunk=8)
    ftq.TRACE_STATS["weight_quantize_calls"] = 0
    prompts = [RNG.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (3, 9, 12, 5)]
    for r, p in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=p, max_new=2))
    eng.run_to_completion(max_steps=100)
    for r, p in enumerate(prompts):  # injected variant: fresh retraces
        eng.submit(Request(rid=10 + r, prompt=p.copy(), max_new=2))
    eng.run_to_completion(max_steps=100, failed_group=1)
    assert ftq.TRACE_STATS["weight_quantize_calls"] == 0, \
        "a traced step re-quantized weights despite the startup hoist"
    assert eng.plans is not None and len(eng.plans) > 0
    want = {"qkv", "mlp", "out"} | ({"moe"} if cfg.moe else set())
    assert want <= eng.plans.categories()
