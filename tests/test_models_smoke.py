"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting shapes and no NaNs (task deliverable f)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, cells_for, get_config, get_smoke_config
from repro.models import get_model, lm_loss
from repro.train.train_step import TrainConfig, init_state, make_train_step

KEY = jax.random.PRNGKey(0)
B, T = 2, 32


def _batch(cfg, key=KEY, T_=T):
    batch = {"tokens": jax.random.randint(key, (B, T_), 0, cfg.vocab_size),
             "loss_mask": jnp.ones((B, T_), jnp.int32)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.vision.n_patches, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(KEY, cfg, max_seq=64)
    logits = model.forward_train(params, _batch(cfg), cfg)
    main = logits[0] if isinstance(logits, tuple) else logits
    assert main.shape == (B, T, cfg.vocab_size)
    assert np.isfinite(np.asarray(main, np.float32)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_smoke_config(arch)
    tcfg = TrainConfig(max_seq=64)
    state = init_state(KEY, cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = _batch(cfg)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # params actually changed somewhere in the tree
    delta = max(
        float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])))
    assert delta > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_remat_matches_no_remat(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(KEY, cfg, max_seq=64)
    batch = _batch(cfg)
    base = model.forward_train(params, batch, cfg)
    rem = model.forward_train(
        params, batch, dataclasses.replace(cfg, remat="full"))
    base = base[0] if isinstance(base, tuple) else base
    rem = rem[0] if isinstance(rem, tuple) else rem
    np.testing.assert_allclose(
        np.asarray(base, np.float32), np.asarray(rem, np.float32), atol=1e-5)


def test_full_configs_match_assignment():
    """The FULL configs carry the exact published hyperparameters."""
    spec = {
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "qwen2-7b": (28, 3584, 28, 4, 18944, 152064),
        "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
        "falcon-mamba-7b": (64, 4096, None, None, 0, 65024),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }
    for arch, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L and cfg.d_model == d and cfg.d_ff == ff
        assert cfg.vocab_size == v
        if h is not None:
            assert cfg.n_heads == h and cfg.n_kv_heads == kv
    ds2 = get_config("deepseek-v2-lite-16b")
    assert (ds2.moe.n_experts, ds2.moe.top_k, ds2.moe.n_shared,
            ds2.moe.d_ff_expert) == (64, 6, 2, 1408)
    assert ds2.mla.kv_lora_rank == 512
    ds3 = get_config("deepseek-v3-671b")
    assert (ds3.n_layers, ds3.d_model, ds3.n_heads) == (61, 7168, 128)
    assert (ds3.moe.n_experts, ds3.moe.top_k, ds3.moe.n_shared,
            ds3.moe.d_ff_expert) == (256, 8, 1, 2048)
    assert ds3.mla.q_lora_rank == 1536 and ds3.mtp


def test_cell_policy():
    """long_500k only for sub-quadratic archs (DESIGN.md §6) — 32 cells."""
    total = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        names = [c.name for c in cells_for(cfg)]
        if arch in ("falcon-mamba-7b", "recurrentgemma-2b"):
            assert "long_500k" in names
        else:
            assert "long_500k" not in names
        total += len(names)
    assert total == 32
