"""Property-based tests (hypothesis) on the paper's core invariants."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    checksum_output_bits,
    disentangle,
    disentangle_oracle_np,
    entangle,
    make_plan,
    plan_lk,
)

SET = settings(max_examples=40, deadline=None)


@st.composite
def plan_case(draw):
    M = draw(st.integers(3, 12))
    w = draw(st.sampled_from([16, 32]))
    if w == 16 and M > 15:
        M = 15
    return make_plan(M, w)


@given(plan_case(), st.integers(0, 2**31 - 1))
@SET
def test_roundtrip_any_failure(plan, seed):
    """Entangled outputs recover exactly from any M-1 streams (eq. 16-19)."""
    rng = np.random.default_rng(seed)
    D = plan.max_output_magnitude
    if D == 0:
        return
    d = rng.integers(-D, D + 1, size=(plan.M, 64)).astype(np.int64)
    # entangled outputs as produced by a linear op: delta = S_l d_prev + d
    delta = ((np.roll(d, 1, 0) << plan.l) + d).astype(np.int32)
    failed = int(rng.integers(0, plan.M))
    rec = np.asarray(disentangle(jnp.asarray(delta), plan, failed=failed))
    np.testing.assert_array_equal(rec, d)
    rec_np = disentangle_oracle_np(delta, plan, failed)
    np.testing.assert_array_equal(rec_np, d)


@given(plan_case(), st.integers(0, 2**31 - 1))
@SET
def test_boundary_values(plan, seed):
    """The eq. (13) range contract is sufficient at its exact boundary."""
    D = plan.max_output_magnitude
    if D == 0:
        return
    d = np.array([[D, -D, D - 1, 1 - D, 0, 1, -1]] * plan.M, dtype=np.int64)
    delta = ((np.roll(d, 1, 0) << plan.l) + d).astype(np.int32)
    for failed in range(plan.M):
        rec = np.asarray(disentangle(jnp.asarray(delta), plan, failed=failed))
        np.testing.assert_array_equal(rec, d)


@given(plan_case(), st.integers(0, 2**31 - 1), st.integers(-64, 64))
@SET
def test_linear_homomorphism(plan, seed, scalar):
    """op(E{c}) == E{op(c)} for scaling — the commutation the whole scheme
    rests on (Sec. III)."""
    rng = np.random.default_rng(seed)
    D = plan.max_output_magnitude // (abs(scalar) + 1)
    if D < 1:
        return
    c = rng.integers(-D, D + 1, size=(plan.M, 32)).astype(np.int32)
    eps = np.asarray(entangle(jnp.asarray(c), plan))
    lhs = (eps.astype(np.int64) * scalar).astype(np.int32)
    d = (c.astype(np.int64) * scalar)
    rhs = ((np.roll(d, 1, 0) << plan.l) + d).astype(np.int32)
    np.testing.assert_array_equal(lhs, rhs)


@given(plan_case(), st.integers(0, 2**31 - 1))
@SET
def test_convolution_recovery(plan, seed):
    """End-to-end: entangle -> integer convolution (the paper's op) ->
    fail-stop -> recover == plain convolution."""
    rng = np.random.default_rng(seed)
    nk = int(rng.integers(2, 9))
    g = rng.integers(-8, 8, size=nk).astype(np.int64)
    bound = max(int(np.abs(g).sum()) * 32, 1)
    A = min(plan.max_output_magnitude // bound, 32)
    if A < 1:  # eq. (13) budget too small for this op (e.g. l=1 collapse)
        return
    c = rng.integers(-A, A + 1, size=(plan.M, 48)).astype(np.int32)
    eps = np.asarray(entangle(jnp.asarray(c), plan))
    delta = np.stack([np.convolve(eps[m].astype(np.int64), g)
                      for m in range(plan.M)]).astype(np.int32)
    d_true = np.stack([np.convolve(c[m].astype(np.int64), g)
                       for m in range(plan.M)])
    assert np.abs(d_true).max() <= plan.max_output_magnitude
    failed = int(rng.integers(0, plan.M))
    rec = np.asarray(disentangle(jnp.asarray(delta), plan, failed=failed))
    np.testing.assert_array_equal(rec, d_true)


@given(st.integers(3, 32), st.sampled_from([16, 32]))
@SET
def test_plan_constraints(M, w):
    """(M-1)l + k <= w, k <= l for every planned configuration (eq. 12)."""
    if w == 16 and M > 15:
        return
    l, k = plan_lk(M, w)
    assert (M - 1) * l + k <= w
    assert 1 <= k <= l


def test_table1_reproduction():
    """Paper Table I — exact (l, k, output bitwidth, checksum bitwidth)."""
    expected = {
        3: (11, 10, 21, 30), 4: (8, 8, 24, 30), 5: (7, 4, 25, 29),
        8: (4, 4, 28, 29), 11: (3, 2, 29, 28), 16: (2, 2, 30, 28),
        32: (1, 1, 31, 27),
    }
    for M, (l, k, bits, cs_bits) in expected.items():
        pl, pk = plan_lk(M, 32)
        plan = make_plan(M, 32)
        assert (pl, pk) == (l, k), M
        assert plan.output_bits == bits, M
        assert checksum_output_bits(M, 32) == cs_bits, M


def test_out_of_range_breaks():
    """Values beyond the range contract are NOT guaranteed recoverable —
    eq. (13) is also necessary (Remark 3)."""
    plan = make_plan(3, 32)
    bad = plan.max_output_magnitude_tight * 4
    d = np.array([[bad], [0], [0]], dtype=np.int64)
    delta = ((np.roll(d, 1, 0) << plan.l) + d).astype(np.int32)
    rec = np.asarray(
        disentangle(jnp.asarray(delta), plan, failed=0)).astype(np.int64)
    assert not np.array_equal(rec, d)


def test_tight_bound_extends_table1():
    """Beyond-paper: the tight bound keeps M=32 usable where eq. (13)
    collapses to zero."""
    plan = make_plan(32, 32)
    assert plan.max_output_magnitude == 0
    D = plan.max_output_magnitude_tight
    assert D > 2**28
    d = np.full((32, 8), D, dtype=np.int64)
    d[::2] *= -1
    delta = ((np.roll(d, 1, 0) << plan.l) + d).astype(np.int32)
    rec = np.asarray(disentangle(jnp.asarray(delta), plan, failed=5))
    np.testing.assert_array_equal(rec, d)
