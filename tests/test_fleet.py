"""Multi-replica fleet invariants: fail-stop migration, warm scale-up,
replica lifecycle, autoscaling.

  * ACCEPTANCE: a 4-replica single-process fleet kills one replica
    mid-decode and every in-flight request completes with tokens
    bit-identical to a no-failure single-engine run — decode-prefix
    resume for short contexts, batched-prefill recompute (with
    regenerated-prefix suppression) otherwise — and the surviving
    replicas' shared ``CompiledPlans.misses`` stays 0;
  * the caller's RequestHandle/TokenRing surface stays valid across a
    migration: an iterator started before the kill streams the full
    no-failure token sequence, never repeats a token, and never learns a
    replica died;
  * queued and mid-prefill requests on the dead replica replay via
    normal batched admission on survivors;
  * spawned replicas reuse the first replica's warm state: no autotune
    re-sweep, no weight re-quantization, the SAME CompiledPlans object;
  * replica lifecycle: STARTING promotes on first heartbeat, DRAINING
    finishes in-flight work then retires, fail-stop is terminal;
  * ScalingPolicy: queue depth spawns, idle low-utilization drains,
    bounds respected.
"""
import dataclasses

import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.kernels import autotune
from repro.ft import quantize
from repro.models import get_model
from repro.serve import (DEAD, DRAINING, HEALTHY, STARTING, Fleet,
                         FleetConfig, ReplicaDead, Request, ScalingPolicy,
                         ServeConfig, ServeEngine)

RNG = np.random.default_rng(23)
_PARAMS_CACHE: dict = {}


def _setup(arch: str, max_seq: int = 48):
    if arch not in _PARAMS_CACHE:
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg, max_seq=max_seq)
        _PARAMS_CACHE[arch] = (cfg, model, params)
    return _PARAMS_CACHE[arch]


def _prompts(vocab, lengths):
    return [RNG.integers(0, vocab, size=n).astype(np.int32)
            for n in lengths]


def _scfg(**kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_seq", 48)
    kw.setdefault("prefill_buckets", (8, 16))
    kw.setdefault("prefill_chunk", 8)
    return ServeConfig(**kw)


def _reference(cfg, scfg, params, prompts, max_new):
    """No-failure single-engine run — the bit-identity oracle."""
    eng = ServeEngine(cfg, scfg, params)
    hs = [eng.submit(Request(rid=i, prompt=p, max_new=max_new))
          for i, p in enumerate(prompts)]
    eng.run_to_completion(max_steps=500)
    return [np.asarray(h.req.out).copy() for h in hs]


# -- acceptance: kill mid-decode, bit-identical completion --------------------


@pytest.mark.parametrize("arch,ft_mode,ft_scope", [
    ("llama3.2-1b", "none", "head"),
    ("llama3.2-1b", "entangle", "all"),
    ("falcon-mamba-7b", "entangle", "head"),
])
def test_kill_mid_decode_completes_bit_identical(arch, ft_mode, ft_scope):
    """The headline guarantee: 4 replicas, kill one mid-decode, every
    request finishes with the no-failure run's exact tokens; surviving
    replicas' (shared) plans never miss."""
    cfg, _, params = _setup(arch)
    scfg = _scfg(ft_mode=ft_mode, ft_scope=ft_scope,
                 token_budget=16 if ft_mode == "none" else 0)
    # short prompts exercise decode-prefix resume; the 15-token ones can
    # outgrow the 16 bucket once a prefix is appended -> recompute path
    prompts = _prompts(cfg.vocab_size, (4, 9, 12, 5, 15, 3, 15, 6))
    ref = _reference(cfg, scfg, params, prompts, max_new=10)

    fleet = Fleet(cfg, scfg, params, FleetConfig(replicas=4))
    hs = [fleet.submit(Request(rid=i, prompt=p, max_new=10))
          for i, p in enumerate(prompts)]
    for _ in range(6):
        fleet.step()
    assert any(h.status == "decoding" for h in hs), "kill must land mid-decode"
    fleet.kill_replica(2)
    fleet.run_to_completion(max_steps=500)

    m = fleet.fleet_metrics()
    assert m["failed"] == 1 and m["router_migrated"] >= 1
    assert fleet.replicas[2].state == DEAD and fleet.replicas[2].failed
    for h, want in zip(hs, ref):
        assert h.status == "done"
        np.testing.assert_array_equal(np.asarray(h.req.out), want)
    for rid, rep in fleet.replicas.items():
        if rep.live and rep.transport.engine.plans is not None:
            assert rep.transport.engine.plans.misses == 0


def test_both_resume_paths_exercised_and_exact():
    """Force one request down each recovery path — decode-prefix resume
    (prompt + prefix fits the largest bucket) and full recompute with
    prefix suppression (it doesn't) — and check both streams match the
    no-failure oracle."""
    cfg, _, params = _setup("llama3.2-1b")
    scfg = _scfg()
    prompts = _prompts(cfg.vocab_size, (4, 15))  # 4+k <= 16; 15+k > 16
    ref = _reference(cfg, scfg, params, prompts, max_new=12)

    fleet = Fleet(cfg, scfg, params, FleetConfig(replicas=3))
    hs = [fleet.submit(Request(rid=i, prompt=p, max_new=12))
          for i, p in enumerate(prompts)]
    # both requests decode for a few steps (k >= 2) before the kill
    for _ in range(7):
        fleet.step()
    assert all(h.status == "decoding" for h in hs)
    # least-loaded dispatch spreads the two requests over distinct
    # replicas; kill each holder (letting the first migration re-land in
    # between) so BOTH recovery paths run, with the third replica as the
    # survivor absorbing everything
    holder0 = fleet.router.records[id(hs[0].req)].replica
    fleet.kill_replica(holder0)
    fleet.step()  # detect + migrate request 0 before the second kill
    holder1 = fleet.router.records[id(hs[1].req)].replica
    if holder1 != holder0:
        fleet.kill_replica(holder1)
    fleet.run_to_completion(max_steps=500)
    m = fleet.fleet_metrics()
    assert m["router_resume_prefix"] >= 1, "short prompt must prefix-resume"
    assert m["router_resume_recompute"] >= 1, "long prompt must recompute"
    for h, want in zip(hs, ref):
        assert h.status == "done"
        np.testing.assert_array_equal(np.asarray(h.req.out), want)


def test_handle_iterator_survives_migration():
    """An iterator opened BEFORE the kill keeps streaming across it:
    full no-failure sequence, no repeats, no exception — the caller
    cannot observe that a replica died."""
    cfg, _, params = _setup("llama3.2-1b")
    scfg = _scfg()
    prompts = _prompts(cfg.vocab_size, (5, 7, 9))
    ref = _reference(cfg, scfg, params, prompts, max_new=10)

    fleet = Fleet(cfg, scfg, params, FleetConfig(replicas=2))
    hs = [fleet.submit(Request(rid=i, prompt=p, max_new=10))
          for i, p in enumerate(prompts)]
    streams = [[] for _ in hs]
    its = [h.tokens() for h in hs]
    # interleave: pull a few tokens from each handle, then kill
    for _ in range(3):
        for toks, it in zip(streams, its):
            toks.append(next(it))
    fleet.kill_replica(0)
    for toks, it in zip(streams, its):
        toks.extend(it)  # drain to completion through the SAME iterator
    for toks, want in zip(streams, ref):
        np.testing.assert_array_equal(np.asarray(toks, np.int32), want)


def test_queued_and_mid_prefill_requests_replay():
    """Requests the dead replica had not started decoding (router-queued
    or mid-prefill on the replica) replay via normal batched admission on
    survivors — same tokens, counted as replays not resumes."""
    cfg, _, params = _setup("llama3.2-1b")
    scfg = _scfg(prefill_chunk=4, max_prefill_per_step=1)
    # long prompts so prefill takes several steps; more requests than
    # fleet slots so some stay router-queued at the kill
    prompts = _prompts(cfg.vocab_size, (16, 16, 16, 16, 16, 16))
    ref = _reference(cfg, scfg, params, prompts, max_new=6)

    fleet = Fleet(cfg, scfg, params, FleetConfig(replicas=2))
    hs = [fleet.submit(Request(rid=i, prompt=p, max_new=6))
          for i, p in enumerate(prompts)]
    fleet.step()  # dispatch + first prefill chunk only
    assert any(h.status == "prefill" for h in hs)
    assert not any(h.status == "decoding" for h in hs)
    fleet.kill_replica(1)
    fleet.run_to_completion(max_steps=500)
    m = fleet.fleet_metrics()
    assert m["router_migrated"] >= 1
    assert m["router_resume_prefix"] == 0 and m["router_resume_recompute"] == 0
    assert m["router_replayed"] >= 1
    for h, want in zip(hs, ref):
        assert h.status == "done"
        np.testing.assert_array_equal(np.asarray(h.req.out), want)


# -- warm scale-up ------------------------------------------------------------


def test_spawn_shares_warm_state_no_resweep():
    """Satellite 6: replica 2..N of identical config reuse replica 1's
    census / CompiledPlans / quantized weights / autotune winners —
    spawning does zero sweeps, zero weight-quantize calls, and shares the
    SAME CompiledPlans object (one pooled ``misses`` counter)."""
    cfg, _, params = _setup("llama3.2-1b")
    scfg = _scfg(ft_mode="entangle", ft_scope="all", blocks="auto")
    fleet = Fleet(cfg, scfg, params, FleetConfig(replicas=1))
    e0 = fleet.replicas[0].transport.engine
    assert e0.plans is not None

    sweeps0 = autotune.stats()["sweeps"]
    wq0 = quantize.TRACE_STATS["weight_quantize_calls"]
    rep1 = fleet._spawn()
    assert autotune.stats()["sweeps"] == sweeps0, "spawn re-swept autotune"
    assert quantize.TRACE_STATS["weight_quantize_calls"] == wq0, \
        "spawn re-quantized protected weights"
    e1 = rep1.transport.engine
    assert e1.plans is e0.plans
    assert e1.ft_params is e0.ft_params
    assert e1.protected_census is e0.protected_census
    assert e1.plans.misses == 0

    # ...and the spawned replica actually serves: run a wave across both
    prompts = _prompts(cfg.vocab_size, (5, 6, 7, 8))
    hs = [fleet.submit(Request(rid=i, prompt=p, max_new=4))
          for i, p in enumerate(prompts)]
    fleet.run_to_completion(max_steps=300)
    assert all(h.status == "done" for h in hs)
    assert e0.plans.misses == 0


def test_warm_state_rejects_config_mismatch():
    """A warm dict from a differently-configured engine must be refused —
    silently serving another program set's plans would be memory-unsafe
    at the kernel level."""
    cfg, _, params = _setup("llama3.2-1b")
    eng = ServeEngine(cfg, _scfg(), params)
    other = dataclasses.replace(_scfg(), max_batch=8)
    with pytest.raises(ValueError, match="differently-configured"):
        ServeEngine(cfg, other, params, warm=eng.warm_state())


# -- lifecycle ----------------------------------------------------------------


def test_replica_lifecycle_and_drain_retirement():
    """STARTING promotes on the first heartbeat; a DRAINING replica takes
    no new dispatches, finishes what it holds, then retires DEAD with
    ``failed=False`` (graceful, distinct from fail-stop)."""
    cfg, _, params = _setup("llama3.2-1b")
    fleet = Fleet(cfg, _scfg(), params, FleetConfig(replicas=2))
    assert all(r.state == STARTING for r in fleet.replicas.values())
    h0 = fleet.submit(Request(rid=0, prompt=_prompts(cfg.vocab_size, (6,))[0],
                              max_new=6))
    fleet.step()
    assert all(r.state == HEALTHY for r in fleet.replicas.values())

    # drain whichever replica holds the request
    holder = fleet.router.records[id(h0.req)].replica
    fleet.replicas[holder].state = DRAINING
    h1 = fleet.submit(Request(rid=1, prompt=_prompts(cfg.vocab_size, (6,))[0],
                              max_new=6))
    fleet.step()
    assert fleet.router.records[id(h1.req)].replica != holder, \
        "DRAINING replica accepted new work"
    fleet.run_to_completion(max_steps=300)
    assert h0.status == "done" and h1.status == "done"
    assert fleet.replicas[holder].state == DEAD
    assert not fleet.replicas[holder].failed
    assert fleet.fleet_metrics()["retired"] == 1


def test_dead_transport_refuses_everything():
    cfg, _, params = _setup("llama3.2-1b")
    fleet = Fleet(cfg, _scfg(), params, FleetConfig(replicas=1))
    tr = fleet.replicas[0].transport
    tr.kill()
    for op in (lambda: tr.step(), lambda: tr.heartbeat(), lambda: tr.idle(),
               lambda: tr.metrics(), lambda: tr.warm_state(),
               lambda: tr.submit(Request(rid=9, prompt=np.zeros(4, np.int32)))):
        with pytest.raises(ReplicaDead):
            op()


def test_kill_last_replica_recovers_via_autoscaling():
    """Killing the only live replica is a full outage: requests wait in
    the router queue until the scaling policy revives the pool, then
    complete with the no-failure tokens."""
    cfg, _, params = _setup("llama3.2-1b")
    scfg = _scfg()
    prompts = _prompts(cfg.vocab_size, (5, 9))
    ref = _reference(cfg, scfg, params, prompts, max_new=6)
    pol = ScalingPolicy(min_replicas=1, max_replicas=2, scale_up_depth=99,
                        decide_every=1)
    fleet = Fleet(cfg, scfg, params, FleetConfig(replicas=1, policy=pol))
    hs = [fleet.submit(Request(rid=i, prompt=p, max_new=6))
          for i, p in enumerate(prompts)]
    for _ in range(3):
        fleet.step()
    fleet.kill_replica(0)
    fleet.run_to_completion(max_steps=300)
    m = fleet.fleet_metrics()
    assert m["failed"] == 1 and m["spawned"] >= 2
    for h, want in zip(hs, ref):
        assert h.status == "done"
        np.testing.assert_array_equal(np.asarray(h.req.out), want)


# -- autoscaling --------------------------------------------------------------


def test_scaling_policy_decisions():
    pol = ScalingPolicy(min_replicas=1, max_replicas=4, scale_up_depth=4,
                        scale_down_util=0.25)
    assert pol.decide(queue_depth=0, healthy=0, utils=[]) == 1  # below min
    assert pol.decide(queue_depth=9, healthy=2, utils=[1.0, 1.0]) == 1
    assert pol.decide(queue_depth=8, healthy=2, utils=[1.0, 1.0]) == 0
    assert pol.decide(queue_depth=0, healthy=2, utils=[0.1, 0.2]) == -1
    assert pol.decide(queue_depth=0, healthy=2, utils=[0.1, 0.9]) == 0
    assert pol.decide(queue_depth=0, healthy=1, utils=[0.0]) == 0  # at min
    assert pol.decide(queue_depth=99, healthy=4, utils=[1.0] * 4) == 0  # at max
    with pytest.raises(ValueError, match="min_replicas"):
        ScalingPolicy(min_replicas=0)
    with pytest.raises(ValueError, match="max_replicas"):
        ScalingPolicy(min_replicas=3, max_replicas=2)


def test_fleet_scales_up_then_drains_idle_replica():
    """Deep queue spawns a (warm) replica; when the burst drains and
    utilization collapses, the policy retires one back toward min."""
    cfg, _, params = _setup("llama3.2-1b")
    scfg = _scfg(token_budget=16)
    pol = ScalingPolicy(min_replicas=1, max_replicas=2, scale_up_depth=2,
                        scale_down_util=0.25, decide_every=2)
    fleet = Fleet(cfg, scfg, params, FleetConfig(replicas=1, policy=pol))
    prompts = _prompts(cfg.vocab_size, (8,) * 10)
    hs = [fleet.submit(Request(rid=i, prompt=p, max_new=6))
          for i, p in enumerate(prompts)]
    fleet.run_to_completion(max_steps=500)
    m = fleet.fleet_metrics()
    assert m["scale_ups"] >= 1
    assert all(h.status == "done" for h in hs)
    # burst is over: keep stepping idle — low utilization drains back
    for _ in range(3 * pol.decide_every):
        fleet.step()
    m = fleet.fleet_metrics()
    assert m["scale_downs"] >= 1
    assert len([r for r in fleet.replicas.values()
                if r.state in (HEALTHY, DRAINING)]) >= pol.min_replicas
