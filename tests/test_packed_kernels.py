"""Packed-int8 weight path: pack/unpack roundtrip properties, packed
fused kernels vs the int64 disentangle oracle for every plan and failed
stream (dense, grouped, conv1d), and the pretuned-cache staleness
contract for the new packed key namespace.

The packed copy stores 4 int8 lanes per int32 word along the contraction
axis (codec.pack_int8); kernels unpack on load with sign-extending shifts.
Packing is a pure storage transform, so every packed kernel result must be
BIT-identical to the int32-container path — healthy and for every
failed-stream index r.
"""
import json

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.entangle import disentangle_oracle_np
from repro.core.plan import make_plan
from repro.kernels import autotune, ops
from repro.kernels.codec import PACK_LANES, pack_int8, unpack_int8

SET = settings(max_examples=8, deadline=None)

PLANS = [(3, 16, None), (4, 32, None), (3, 32, "dualword"), (8, 32, None)]


# ---------------------------------------------------------- roundtrip ----

@st.composite
def pack_case(draw):
    ndim = draw(st.integers(1, 3))
    shape = tuple(draw(st.integers(1, 13)) for _ in range(ndim))
    axis = draw(st.integers(0, ndim - 1))
    seed = draw(st.integers(0, 2**31 - 1))
    return shape, axis, seed


@given(pack_case())
@SET
def test_pack_unpack_roundtrip_full_int8_range(case):
    """pack -> unpack is bit-exact over the FULL int8 value range
    [-128, 127], any shape, any axis, including non-multiple-of-4 axis
    lengths (zero-padded words; unpack slices back to n)."""
    shape, axis, seed = case
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, size=shape).astype(np.int32)
    p = pack_int8(jnp.asarray(x), axis=axis)
    n = shape[axis]
    assert p.shape[axis] == -(-n // PACK_LANES)
    back = unpack_int8(p, axis=axis, n=n)
    np.testing.assert_array_equal(np.asarray(back), x)


def test_pack_boundary_values_exact():
    """The sign-extension edge cases: -128, -1, 0, 127 survive packing in
    every lane position."""
    vals = np.array([-128, -1, 0, 127, -127, 1, 64, -64], np.int32)
    p = pack_int8(jnp.asarray(vals[:, None]), axis=0)
    np.testing.assert_array_equal(
        np.asarray(unpack_int8(p, axis=0, n=8))[:, 0], vals)


# --------------------------------------------- packed kernels vs oracle ----
# Deterministic fixed shapes per plan (NOT hypothesis-drawn): each unique
# shape is a fresh interpret-mode kernel compile for every (failed, packed)
# variant, so randomized shapes would blow the suite budget on compiles
# without adding coverage — the value space is already exercised densely,
# and the roundtrip property above fuzzes the codec itself. K=13 keeps the
# non-multiple-of-4 packing tail in play on every kernel test.


@pytest.mark.parametrize("M,w,temp", PLANS)
def test_packed_matmul_matches_oracle_all_failures(M, w, temp):
    """Packed dense fused GEMM == int64 disentangle oracle and == the
    unpacked kernel, for failure-free extraction and every failed r."""
    plan = make_plan(M, w, temp=temp)
    B, K, N = 6, 13, 9
    rng = np.random.default_rng(M * 1000 + w)
    lim = min(max(int(np.sqrt(plan.max_output_magnitude / K)) // 2, 1), 15)
    c = jnp.asarray(rng.integers(-lim, lim + 1,
                                 size=(plan.M, B, K)).astype(np.int32))
    g = jnp.asarray(rng.integers(-lim, lim + 1,
                                 size=(K, N)).astype(np.int32))
    gp = pack_int8(g, axis=0)
    bl = {"bb": 16, "bn": 32, "bk": 32}

    delta = ops.entangled_matmul(c, g, plan, blocks=bl)
    for r in [None] + list(range(plan.M)):
        packed = ops.entangled_matmul(c, gp, plan, fuse_epilogue=True,
                                      failed=r, packed=True, blocks=bl)
        oracle = disentangle_oracle_np(np.asarray(delta), plan,
                                       0 if r is None else r)
        np.testing.assert_array_equal(np.asarray(packed), oracle)
        unpacked = ops.entangled_matmul(c, g, plan, fuse_epilogue=True,
                                        failed=r, blocks=bl)
        np.testing.assert_array_equal(np.asarray(packed),
                                      np.asarray(unpacked))


@pytest.mark.parametrize("M,w,temp", PLANS)
def test_packed_grouped_matmul_matches_all_failures(M, w, temp):
    """Packed grouped (per-expert) fused GEMM == the unpacked kernel ==
    oracle for every failed stream."""
    plan = make_plan(M, w, temp=temp)
    E, C, K, N = 3, 4, 13, 7
    rng = np.random.default_rng(M * 1000 + w + 1)
    lim = min(max(int(np.sqrt(plan.max_output_magnitude / K)) // 2, 1), 15)
    c = jnp.asarray(rng.integers(-lim, lim + 1,
                                 size=(plan.M, E, C, K)).astype(np.int32))
    g = jnp.asarray(rng.integers(-lim, lim + 1,
                                 size=(E, K, N)).astype(np.int32))
    gp = pack_int8(g, axis=1)
    bl = {"bb": 8, "bn": 16, "bk": 16}

    delta = ops.entangled_matmul_grouped(c, g, plan, blocks=bl)
    for r in [None] + list(range(plan.M)):
        packed = ops.entangled_matmul_grouped(
            c, gp, plan, fuse_epilogue=True, failed=r, packed=True,
            blocks=bl)
        oracle = disentangle_oracle_np(
            np.asarray(delta).reshape(plan.M, -1), plan,
            0 if r is None else r)
        np.testing.assert_array_equal(
            np.asarray(packed).reshape(plan.M, -1), oracle)
        unpacked = ops.entangled_matmul_grouped(
            c, g, plan, fuse_epilogue=True, failed=r, blocks=bl)
        np.testing.assert_array_equal(np.asarray(packed),
                                      np.asarray(unpacked))


@pytest.mark.parametrize("M,w,temp", PLANS)
def test_packed_conv1d_matches_all_failures(M, w, temp):
    """Packed depthwise conv1d (weights packed along D) == the unpacked
    kernel for every failed stream."""
    plan = make_plan(M, w, temp=temp)
    B, D, T, kf = 2, 13, 12, 3
    rng = np.random.default_rng(M * 1000 + w + 2)
    lim = min(max(plan.max_output_magnitude // (kf * 127) // 2, 1), 15)
    x = jnp.asarray(rng.integers(-lim, lim + 1,
                                 size=(plan.M, B, D, T)).astype(np.int32))
    w = jnp.asarray(rng.integers(-lim, lim + 1,
                                 size=(D, kf)).astype(np.int32))
    wp = pack_int8(w, axis=0)
    bl = {"bd": 16, "bt": 64}

    for r in [None] + list(range(plan.M)):
        packed = ops.entangled_conv1d(x, wp, plan, fuse_epilogue=True,
                                      failed=r, packed=True, blocks=bl)
        unpacked = ops.entangled_conv1d(x, w, plan, fuse_epilogue=True,
                                        failed=r, blocks=bl)
        np.testing.assert_array_equal(np.asarray(packed),
                                      np.asarray(unpacked))


# -------------------------------------------------- pretuned staleness ----

def test_pretuned_stale_keys_dropped_with_warning(tmp_path, monkeypatch):
    """A pretuned file carrying keys from an op namespace this build no
    longer tunes must load its VALID keys (cold hit) and drop the stale
    ones with a warning — never crash, never inflate coverage."""
    pre = tmp_path / "pretuned"
    pre.mkdir()
    backend = ops.resolve_backend()
    good = autotune.cache_key("entangled_matmul", (4, 8, 32, 16), backend,
                              ("l8", "dualword", "fused", "packed"))
    stale_op = "entangled_matmul_v0|4x8x32x16|" + backend + "|fused"
    stale_be = autotune.cache_key("entangled_matmul", (4, 8, 32, 16),
                                  "no_such_backend", ("fused",))
    (pre / "gen.json").write_text(json.dumps({
        "_meta": {"version": 1},
        good: {"bb": 16, "bn": 16, "bk": 32},
        stale_op: {"bb": 8, "bn": 8, "bk": 8},
        stale_be: {"bb": 8, "bn": 8, "bk": 8},
    }))
    monkeypatch.setattr(autotune, "PRETUNED_DIR", pre)
    cache = autotune.AutotuneCache(str(tmp_path / "user.json"))
    with pytest.warns(RuntimeWarning, match="stale"):
        hit = cache.get(good)
    assert hit == {"bb": 16, "bn": 16, "bk": 32}
    assert cache.get(stale_op) is None
    assert cache.get(stale_be) is None
    assert cache.sweeps == 0


def test_shipped_pretuned_file_has_packed_generation():
    """The shipped interpret_cpu seed must carry the packed-flag keys the
    packed-by-default engine warms, alongside the legacy unpacked ones —
    and every key must parse into a known namespace."""
    f = autotune.PRETUNED_DIR / "interpret_cpu.json"
    data = json.loads(f.read_text())
    keys = [k for k in data if k != "_meta"]
    packed = [k for k in keys if k.endswith(",packed") or ",packed," in k]
    assert packed, "no packed-generation keys shipped"
    unpacked = [k for k in keys if "packed" not in k]
    assert unpacked, "legacy unpacked keys dropped"
    for k in keys:
        assert autotune.AutotuneCache._known_namespace(k, ops_too=True), k
