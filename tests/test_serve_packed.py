"""Token-packed admission (ServeConfig.token_budget): one fixed-shape
token-parallel program per step across ALL in-flight admission batches.

  * the packed x fail-stop bitwise matrix: token-packed admission produces
    tokens bit-identical to the per-batch chunked pipeline it replaces,
    for dense/ssm/hybrid x ft_scope head/all x an injected fail-stop in
    every group — packing (WHICH rows share a program, and at WHAT
    offsets) must never change tokens or break the entangled roll-forward;
  * ragged edge cases: a budget smaller than one bucket, a single true
    token remaining in a row, mixed-bucket co-packing (rows from a
    bucket-8 and a bucket-16 batch in ONE program), and a cancel
    mid-pack — all served by the SAME compiled [Rp, Cp] shape;
  * plan discipline: the packed engine's census holds exactly one prefill
    entry set, CompiledPlans.misses == 0 and zero new registry entries
    after a full wave whatever the packing mix;
  * accounting: metrics['packed_tokens'] counts TRUE prompt tokens (bucket
    padding never packed), packed_calls == prefill_calls, and
    packed_batches_peak proves real co-packing;
  * loud config validation: budget/chunk geometry errors die at engine
    construction, not inside a traced step.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import Request, ServeConfig, ServeEngine

RNG = np.random.default_rng(31)
_PARAMS_CACHE: dict = {}

LENGTHS = [5, 6, 12, 3, 4, 6]
MAX_NEW = [1, 2, 3, 2, 1, 2]
BUCKETS = (8, 16)


def _setup(arch: str, max_seq: int = 48):
    if arch not in _PARAMS_CACHE:
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg, max_seq=max_seq)
        _PARAMS_CACHE[arch] = (cfg, model, params)
    return _PARAMS_CACHE[arch]


def _prompts(cfg, lengths):
    return [RNG.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
            for n in lengths]


def _run(cfg, params, *, token_budget, scope="head", ft=True,
         failed_group=None, refill=True, lengths=LENGTHS, max_new=MAX_NEW):
    global RNG
    RNG = np.random.default_rng(31)  # same prompts for every variant
    scfg = ServeConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                       prefill_buckets=BUCKETS, refill=refill,
                       token_budget=token_budget,
                       **({"ft_mode": "entangle", "ft_M": 4,
                           "ft_scope": scope} if ft else {}))
    eng = ServeEngine(cfg, scfg, params)
    for r, p in enumerate(_prompts(cfg, lengths)):
        eng.submit(Request(rid=r, prompt=p, max_new=max_new[r]))
    eng.run_to_completion(max_steps=500, failed_group=failed_group)
    return {r.rid: np.asarray(r.out) for r in eng.done}, eng


@pytest.mark.parametrize("scope", ["head", "all"])
@pytest.mark.parametrize(
    "arch", ["llama3.2-1b", "falcon-mamba-7b", "recurrentgemma-2b"])
def test_packed_failstop_bitwise_matrix(arch, scope):
    """Packed vs per-batch chunked admission, healthy AND with a fail-stop
    injected into every group: identical tokens per request. Slot -> group
    stays slot % M, activation quantization is per row, and the entangled
    recovery is exact, so HOW rows were packed — co-residents, offsets,
    pad rows — can never move a request's integer grid."""
    cfg, _, params = _setup(arch)
    ref, _ = _run(cfg, params, token_budget=0, scope=scope)
    assert set(ref) == set(range(len(LENGTHS)))
    for fg in [None] + list(range(4)):
        out, eng = _run(cfg, params, token_budget=16, scope=scope,
                        failed_group=fg)
        assert eng.metrics["packed_calls"] > 0
        assert eng.metrics["packed_tokens"] == sum(LENGTHS), \
            "bucket padding leaked into the packed-token count"
        assert eng.metrics["packed_batches_peak"] >= 2, \
            "matrix never co-packed rows from two admission batches"
        for r in ref:
            np.testing.assert_array_equal(
                ref[r], out[r],
                err_msg=f"{arch} scope={scope} failed_group={fg} rid={r} "
                        f"(packing or roll-forward changed tokens)")


def test_packed_one_compiled_shape_no_misses():
    """Whatever the packing mix, the engine runs ONE [Rp, Cp] prefill
    program: a single census entry set, zero CompiledPlans lookup misses
    and zero NEW registry entries after the wave."""
    cfg, _, params = _setup("llama3.2-1b")
    out, eng = _run(cfg, params, token_budget=16, scope="all")
    assert set(out) == set(range(len(LENGTHS)))
    Rp, Cp = 16 // 8, 8
    assert set(eng.census["prefill"]) == {(Rp, Cp)}, \
        "packed admission retraced a second prefill shape"
    assert eng.plans.misses == 0, \
        "a packing mix requested a shape the startup census missed"
    n_entries = len(eng.registry.census())
    out2, eng2 = _run(cfg, params, token_budget=16, scope="all",
                      lengths=[3, 9, 15, 2, 8, 12],
                      max_new=[2, 1, 2, 3, 1, 2])
    assert set(eng2.census["prefill"]) == {(Rp, Cp)}
    assert eng2.plans.misses == 0
    assert len(eng2.registry.census()) == n_entries, \
        "a different packing mix created new plan-registry entries"


def test_packed_budget_smaller_than_bucket():
    """token_budget=8 (ONE chunk-wide row per step) is smaller than every
    bucket — rows just take more steps; tokens stay bit-identical."""
    cfg, _, params = _setup("llama3.2-1b")
    ref, _ = _run(cfg, params, token_budget=0)
    out, eng = _run(cfg, params, token_budget=8)
    assert eng.metrics["packed_tokens"] == sum(LENGTHS)
    for r in ref:
        np.testing.assert_array_equal(ref[r], out[r], err_msg=f"rid={r}")


def test_packed_single_token_remaining():
    """A 9-token prompt with chunk 8 leaves ONE true token for its second
    packed row — the [Rp, Cp] program serves it (7 pad positions masked)
    with tokens bit-identical to chunked admission."""
    cfg, _, params = _setup("llama3.2-1b")
    lengths, max_new = [9, 5, 15, 3], [2, 1, 2, 2]
    ref, _ = _run(cfg, params, token_budget=0, lengths=lengths,
                  max_new=max_new)
    out, eng = _run(cfg, params, token_budget=16, lengths=lengths,
                    max_new=max_new)
    assert eng.metrics["packed_tokens"] == sum(lengths)
    for r in ref:
        np.testing.assert_array_equal(ref[r], out[r], err_msg=f"rid={r}")


def test_packed_mixed_bucket_copacking():
    """Rows from a bucket-8 batch and a bucket-16 batch share one packed
    program — exactly what per-batch chunking cannot do (one bucket per
    [Bp, bucket] call). packed_batches_peak >= 2 is the evidence, and the
    refill counter still tracks mid-flight admissions."""
    cfg, _, params = _setup("llama3.2-1b")
    # two single-request batches in different buckets: the 12-token
    # prompt buckets to 16, the 5-token to 8 — pack_rows (shortest
    # remaining first) must put the bucket-8 row AND a bucket-16 row in
    # the same 2-row program on the first packed step
    lengths, max_new = [12, 5], [3, 2]
    ref, _ = _run(cfg, params, token_budget=0, lengths=lengths,
                  max_new=max_new)
    out, eng = _run(cfg, params, token_budget=16, lengths=lengths,
                    max_new=max_new)
    assert eng.metrics["packed_batches_peak"] >= 2, \
        "mixed-bucket wave never co-packed two admission batches"
    assert eng.metrics["refill_admissions"] > 0
    for r in ref:
        np.testing.assert_array_equal(ref[r], out[r], err_msg=f"rid={r}")


def test_packed_cancel_mid_pack():
    """cancel() between packed steps: the row stops packing immediately
    (its remaining tokens are never spent), its reservation frees, other
    requests' tokens are untouched, and an all-cancelled batch drains
    without compute."""
    cfg, _, params = _setup("llama3.2-1b")
    eng = ServeEngine(
        cfg, ServeConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                         prefill_buckets=(8, 16, 32),
                         token_budget=16), params)
    rng = np.random.default_rng(31)
    long = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 30)
                   .astype(np.int32), max_new=4)
    eng.submit(long)
    eng.step()  # packs the first chunk(s) of the long prompt
    assert long.status == "prefill" and eng._inflight
    toks_before = eng.metrics["packed_tokens"]
    eng.cancel(long)
    assert long.status == "cancelled" and not eng._reserved
    short = Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, 6)
                    .astype(np.int32), max_new=3)
    eng.submit(short)
    done = eng.run_to_completion(max_steps=100)
    assert [r.rid for r in done] == [1] and len(short.out) == 3
    assert eng.idle(), "cancelled batch never drained from _inflight"
    # the cancelled row packed nothing after the cancel
    assert eng.metrics["packed_tokens"] == toks_before + 6


def test_packed_boundary_mode():
    """token_budget composes with refill=False: one admission batch at a
    time (refill_admissions == 0), tokens still bit-identical."""
    cfg, _, params = _setup("llama3.2-1b")
    ref, _ = _run(cfg, params, token_budget=0, refill=False)
    out, eng = _run(cfg, params, token_budget=16, refill=False)
    assert eng.metrics["refill_admissions"] == 0
    assert eng.metrics["packed_calls"] > 0
    for r in ref:
        np.testing.assert_array_equal(ref[r], out[r], err_msg=f"rid={r}")


def test_packed_accounting():
    """prefill_calls counts packed program invocations (== packed_calls),
    packed_tokens counts exactly the true prompt tokens, and no landing
    is lost: every request lands through the shared landing tail."""
    cfg, _, params = _setup("llama3.2-1b")
    out, eng = _run(cfg, params, token_budget=16, ft=False)
    assert set(out) == set(range(len(LENGTHS)))
    assert eng.prefill_calls == eng.metrics["packed_calls"] > 0
    assert eng.metrics["packed_tokens"] == sum(LENGTHS)
    assert eng.metrics["landings"] >= 2, \
        "the wave should land several admission batches"


def test_packed_config_validation():
    """Geometry errors die loudly at engine construction."""
    cfg, _, params = _setup("llama3.2-1b")
    def mk(**kw):
        ServeEngine(cfg, ServeConfig(max_batch=4, max_seq=48, **kw), params)
    with pytest.raises(ValueError, match="token_budget"):
        mk(token_budget=-8, prefill_chunk=8)
    with pytest.raises(ValueError, match="prefill_chunk > 0"):
        mk(token_budget=16)  # packed requires chunked admission
    with pytest.raises(ValueError, match="multiple"):
        mk(token_budget=12, prefill_chunk=8)
    with pytest.raises(ValueError, match="max_batch"):
        mk(token_budget=64, prefill_chunk=8)  # 8 rows > 4 slots
