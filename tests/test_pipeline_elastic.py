"""Multi-device features that need >1 device: pipeline parallelism and
elastic checkpoint resharding. The main test process is pinned to 1 CPU
device (dry-run rules), so these run in a subprocess with
--xla_force_host_platform_device_count=4.
"""
import pathlib
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4 " + os.environ.get("XLA_FLAGS", "")
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

assert len(jax.devices()) == 4

# ---------------- pipeline parallelism: 4 stages == sequential --------------
from repro.dist.pipeline import make_layer_stage, pipeline_stack, split_stages

L, D, MB, NMICRO = 8, 16, 4, 6
key = jax.random.PRNGKey(0)
Ws = jax.random.normal(key, (L, D, D)) * (0.5 / np.sqrt(D))

def layer_fn(W, x):
    return jnp.tanh(x @ W)

# sequential reference
def seq(x):
    for i in range(L):
        x = layer_fn(Ws[i], x)
    return x

x_micro = jax.random.normal(jax.random.PRNGKey(1), (NMICRO, MB, D))
ref = jax.vmap(seq)(x_micro)

mesh = jax.make_mesh((4,), ("stage",))
stage_params = split_stages(Ws, 4)
out = pipeline_stack(make_layer_stage(layer_fn), stage_params, x_micro,
                     mesh=mesh, axis="stage")
err = float(jnp.abs(out - ref).max())
assert err < 1e-5, f"pipeline mismatch {err}"
print("PIPELINE_OK", err)

# ---------------- elastic checkpoint resharding: (2,2) -> (4,1) -------------
from repro.train.checkpoint import CheckpointManager
import tempfile

with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mesh_a = jax.make_mesh((2, 2), ("data", "model"))
    sh_a = NamedSharding(mesh_a, P("data", "model"))
    w = jax.device_put(jnp.arange(64.0).reshape(8, 8), sh_a)
    mgr.save({"w": w}, 1, blocking=True)

    mesh_b = jax.make_mesh((4, 1), ("data", "model"))
    sh_b = {"w": NamedSharding(mesh_b, P("data", None))}
    restored, _ = mgr.restore({"w": w}, shardings=sh_b)
    assert restored["w"].sharding == sh_b["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(64.0).reshape(8, 8))
print("ELASTIC_OK")

# ---------------- entangled grad sync across REAL data-parallel ranks -------
from jax.experimental.shard_map import shard_map
from repro.dist.collectives import ft_grad_sync

mesh_c = jax.make_mesh((4,), ("data",))
g_local = jax.random.normal(jax.random.PRNGKey(2), (4, 1024))  # per-rank grads

def sync(g):
    out, _ = ft_grad_sync({"g": g[0]}, axis_name="data", n_replicas=4, M=4,
                          failed_block=2)
    return out["g"][None]

synced = shard_map(sync, mesh=mesh_c, in_specs=(P("data"),),
                   out_specs=P("data"), check_rep=False)(g_local)
want = np.mean(np.asarray(g_local), axis=0)
got = np.asarray(synced)
for r in range(4):
    err = np.abs(got[r] - want).max()
    assert err < 1e-3, (r, err)
print("FT_COLLECTIVE_OK")
"""


@pytest.mark.parametrize("_", [0])
def test_pipeline_elastic_ftsync_multidevice(_, tmp_path):
    script = tmp_path / "multidev.py"
    script.write_text(_SCRIPT)
    repo = pathlib.Path(__file__).resolve().parents[1]
    env = {"PYTHONPATH": str(repo / "src"), "PATH": "/usr/bin:/bin",
           "HOME": "/tmp"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k.startswith(("JAX", "XLA")) is False and k not in env})
    res = subprocess.run(
        [sys.executable, str(script)], capture_output=True, text=True,
        env=env, timeout=540)
    assert res.returncode == 0, res.stderr[-3000:]
    assert "PIPELINE_OK" in res.stdout
    assert "ELASTIC_OK" in res.stdout
    assert "FT_COLLECTIVE_OK" in res.stdout
