"""Entangled-domain chain fusion: one entangle, N GEMMs, one extract.

Three layers of evidence:

  * the standalone :func:`repro.ft.protected.entangled_chain` executor
    rolls a 2-hop and a genuinely-feasible 3-hop chain forward
    BIT-identically for every single failed stream, at any chain point —
    and falls back to per-hop extraction (still bit-identical under
    failure) when :func:`~repro.ft.quantize.chain_budget` says the plan
    has no headroom for the chain;
  * the engine matrix: decode + CHUNKED prefill across protection scopes,
    fanout codec sharing on (``ft_chain=True``, the default) vs off, with
    a fail-stop injected on every step into every group — all token
    streams bit-identical;
  * the census exposes the chainable fanout site groups on the compiled
    plans (``engine.plans.chains``) at plan-compile time.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.core.plan import make_plan
from repro.ft.protected import entangled_chain, protected_matmul
from repro.ft.quantize import chain_budget
from repro.models import get_model
from repro.serve import Request, ServeConfig, ServeEngine

RNG = np.random.default_rng(23)


# ----------------------------------------------- standalone executor ----

def _chain_weights(depths, n_last, rng):
    """Per-hop float weights [K_i, K_{i+1}] for contraction depths
    ``depths`` ending in an ``n_last``-wide output."""
    dims = list(depths) + [n_last]
    return [rng.standard_normal((dims[i], dims[i + 1])).astype(np.float32)
            for i in range(len(depths))]


def _assert_chain_rolls_forward(plan, depths, n_last, rows=7):
    rng = np.random.default_rng(hash((plan.M, tuple(depths))) % 2**32)
    x = rng.standard_normal((rows, depths[0])).astype(np.float32)
    ws = _chain_weights(depths, n_last, rng)
    healthy = np.asarray(entangled_chain(x, ws, plan=plan))
    assert healthy.shape == (rows, n_last)
    assert np.isfinite(healthy).all()
    for r in range(plan.M):
        injected = np.asarray(
            entangled_chain(x, ws, plan=plan, failed_group=r))
        np.testing.assert_array_equal(
            healthy, injected, err_msg=f"failed_group={r} depths={depths}")
    return healthy


def test_chain_two_hop_feasible_bit_identical():
    """make_plan(4, 32) has budget 10 for an (8, 6)-deep 2-hop chain: the
    fused chain path (single extract) is exercised, and every failed
    stream recovers bit-identically."""
    plan = make_plan(4, 32)
    assert chain_budget(plan, (8, 6)) >= 1  # the FUSED path, not fallback
    _assert_chain_rolls_forward(plan, (8, 6), n_last=5)


def test_chain_three_hop_feasible_bit_identical():
    """A genuine 3-GEMM chain needs the wide plan: make_plan(8, 32) holds
    budget >= 1 for depths (4, 3, 2) — one entangle, THREE GEMMs, one
    extract, exact under any single failure at any chain point."""
    plan = make_plan(8, 32)
    assert chain_budget(plan, (4, 3, 2)) >= 1
    _assert_chain_rolls_forward(plan, (4, 3, 2), n_last=3)


def test_chain_infeasible_falls_back_per_hop():
    """make_plan(4, 32) cannot absorb a 3-hop amplification (budget 0):
    the executor must fall back to per-hop extraction — same protection,
    still bit-identical under every failure, and numerically equal to
    explicitly chaining protected_matmul calls."""
    plan = make_plan(4, 32)
    assert chain_budget(plan, (8, 6, 4)) == 0
    healthy = _assert_chain_rolls_forward(plan, (8, 6, 4), n_last=5)
    rng = np.random.default_rng(hash((plan.M, (8, 6, 4))) % 2**32)
    x = rng.standard_normal((7, 8)).astype(np.float32)
    ws = _chain_weights((8, 6, 4), 5, rng)
    y = x
    for w in ws:
        y = protected_matmul(y, w, plan=plan)
    np.testing.assert_array_equal(healthy, np.asarray(y))


def test_chain_single_hop_equals_protected_matmul():
    """A length-1 'chain' is just a protected GEMM — bit-identical to
    protected_matmul (trivial-chain degeneration guard)."""
    plan = make_plan(4, 32)
    rng = np.random.default_rng(3)
    x = rng.standard_normal((6, 9)).astype(np.float32)
    w = rng.standard_normal((9, 5)).astype(np.float32)
    np.testing.assert_array_equal(
        np.asarray(entangled_chain(x, [w], plan=plan)),
        np.asarray(protected_matmul(x, w, plan=plan)))


# ------------------------------------------------------ engine matrix ----

_PARAMS_CACHE: dict = {}


def _setup(arch="llama3.2-1b", max_seq=48):
    if arch not in _PARAMS_CACHE:
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg, max_seq=max_seq)
        _PARAMS_CACHE[arch] = (cfg, params)
    return _PARAMS_CACHE[arch]


def _wave(eng, prompts, max_new=3, failed_group=None):
    """One request wave on an ALREADY-BOOTED engine (waves reuse the
    engine so the matrix costs boots-per-scope, not boots-per-run)."""
    for r, p in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=p.copy(), max_new=max_new))
    done = eng.run_to_completion(max_steps=500, failed_group=failed_group)
    out = {r.rid: np.asarray(r.out) for r in done}
    eng.done = []
    return out


@pytest.mark.parametrize("scope", ["qkv", "out", "all"])
def test_engine_chain_matrix_bit_identical(scope):
    """Decode + chunked prefill, per scope: fanout-chained codec ON (the
    default) equals chained-OFF bitwise on healthy runs, and the chained
    engine rolls EVERY injected failed group forward to the same
    tokens."""
    cfg, params = _setup()
    prompts = [RNG.integers(0, cfg.vocab_size, size=int(RNG.integers(5, 11)))
               .astype(np.int32) for _ in range(4)]
    base = dict(max_batch=4, max_seq=48, ft_mode="entangle", ft_M=4,
                ft_scope=scope, prefill_chunk=4)
    off = ServeEngine(cfg, ServeConfig(**base, ft_chain=False), params)
    ref = _wave(off, prompts)
    assert set(ref) == set(range(4))

    on = ServeEngine(cfg, ServeConfig(**base), params)
    healthy = _wave(on, prompts)
    for r in ref:
        np.testing.assert_array_equal(
            ref[r], healthy[r], err_msg=f"scope={scope} chain on≠off rid={r}")
    for fg in range(4):
        injected = _wave(on, prompts, failed_group=fg)
        for r in ref:
            np.testing.assert_array_equal(
                ref[r], injected[r],
                err_msg=f"scope={scope} failed_group={fg} rid={r}")


def test_census_exposes_fanout_chain_groups():
    """The startup census marks the fanout site groups as chainable on the
    compiled plans — the attention Q/K/V and MLP gate/up groups of the
    dense arch at scope=all."""
    cfg, params = _setup()
    eng = ServeEngine(
        cfg, ServeConfig(max_batch=4, max_seq=48, ft_mode="entangle",
                         ft_M=4, ft_scope="all"), params)
    chains = eng.plans.chains
    assert ("qkv.q", "qkv.k", "qkv.v") in chains
    assert ("mlp.gate", "mlp.up") in chains
