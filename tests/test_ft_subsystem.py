"""Unified protected-GEMM subsystem (repro.ft) invariants.

  * protected_matmul recovery is EXACT: for every failed group r, the
    fail-stop-injected output equals the healthy output bitwise — fused
    Pallas, unfused Pallas and XLA paths, contiguous and round-robin
    grouping, row counts that do and do not divide into M groups;
  * the integer path is faithful: dequantized outputs approximate the
    float GEMM within the quantization step;
  * the activation budget honors the plan's eq. (13) output bound for the
    full contraction depth;
  * the PlanRegistry keys entries by (site, shape, M, backend), clamps
    default blocks to the call shape, and its census lists every site;
  * the shipped pre-tuned seed cache (kernels/pretuned/interpret_cpu.json)
    makes a COLD engine startup with blocks='auto' a pure cache hit — no
    sweep runs even with an empty user cache file.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.plan import make_plan
from repro.ft import (FTContext, PlanRegistry, activation_budget,
                      default_blocks, group_order, protected_matmul,
                      quantize_acts, quantize_weight)

RNG = np.random.default_rng(23)


def _xw(R=10, K=24, N=16):
    x = jnp.asarray(RNG.normal(size=(R, K)).astype(np.float32))
    w = jnp.asarray(RNG.normal(size=(K, N)).astype(np.float32))
    return x, w


@pytest.mark.parametrize("use_pallas,fuse", [(True, True), (True, False),
                                             (False, False)])
@pytest.mark.parametrize("R", [8, 10])  # 10: pads 2 zero rows to M=4 groups
def test_protected_matmul_failstop_exact(use_pallas, fuse, R):
    plan = make_plan(4, 32)
    x, w = _xw(R=R)
    healthy = protected_matmul(x, w, plan=plan, use_pallas=use_pallas,
                               fuse_epilogue=fuse)
    assert healthy.shape == (R, w.shape[1])
    for r in range(plan.M):
        injected = protected_matmul(x, w, plan=plan, failed_group=r,
                                    use_pallas=use_pallas, fuse_epilogue=fuse)
        np.testing.assert_array_equal(np.asarray(healthy),
                                      np.asarray(injected),
                                      err_msg=f"failed_group={r}")


def test_protected_matmul_faithful_and_grouping_invariant():
    """Quantize-dequantize stays within one quantization step of the float
    GEMM, and contiguous vs round-robin grouping produce identical values
    (grouping permutes streams, never the math)."""
    plan = make_plan(4, 32)
    x, w = _xw(R=8, K=24, N=16)
    ref = np.asarray(x) @ np.asarray(w)
    got = np.asarray(protected_matmul(x, w, plan=plan))
    _, w_scale = quantize_weight(w)
    _, a_scale = quantize_acts(x, plan, x.shape[1])
    # worst-case rounding: K terms, each off by <= half a grid step per
    # operand (cross term negligible and covered by the 0.25 slack).
    # a_scale is PER ROW ([R, 1]); the coarsest row's grid bounds them all.
    K = x.shape[1]
    a_min = float(np.min(np.asarray(a_scale)))
    bound = K * (0.5 * np.max(np.abs(w)) / a_min
                 + 0.5 * np.max(np.abs(x)) / float(w_scale)
                 + 0.25 / (a_min * float(w_scale)))
    assert np.max(np.abs(got - ref)) <= bound
    rr = np.asarray(protected_matmul(x, w, plan=plan, contiguous=False))
    cont = np.asarray(protected_matmul(x, w, plan=plan, contiguous=True))
    # recovery is exact in BOTH layouts, so outputs match row-for-row —
    # grouping only re-buckets rows onto streams, never changes the math
    np.testing.assert_array_equal(rr, cont)


def test_activation_budget_honors_eq13():
    plan = make_plan(4, 32)
    for K in (7, 64, 4096):
        b = activation_budget(plan, K)
        assert b >= 1 and K * b * 127 <= max(plan.max_output_magnitude,
                                             K * 127)
        if b > 1:  # non-degenerate budgets must fit exactly
            assert K * b * 127 <= plan.max_output_magnitude


def test_group_order_roundtrip():
    order, inv = group_order(12, 4)
    assert (order[inv] == np.arange(12)).all()
    # row -> group = row % M: group g holds rows g, g+M, g+2M, ...
    assert list(order[:3]) == [0, 4, 8]


def test_registry_keys_blocks_and_census():
    plan = make_plan(4, 32)
    reg = PlanRegistry(plan)
    e = reg.entry("qkv.q", rows=4, K=64, N=48, backend="interpret")
    assert e.shape == (4, 1, 64, 48)  # rows pad to M groups -> 1 row/group
    assert e.blocks == {"bb": 8, "bn": 64, "bk": 64}  # shape-clamped pow2
    assert reg.get("qkv.q", e.shape, "interpret") is e
    # same site, other shape -> distinct entry; census lists both
    e2 = reg.entry("qkv.q", rows=128, K=64, N=48, backend="interpret")
    assert e2 is not e and e2.shape == (4, 32, 64, 48)
    assert set(reg.census()) == {("qkv.q", e.shape), ("qkv.q", e2.shape)}
    assert default_blocks(1, 2048, 300) == {"bb": 8, "bn": 256, "bk": 256}


def test_ftcontext_scopes():
    reg = PlanRegistry(make_plan(4, 32))
    for scope, protected in [("head", ["head"]),
                             ("qkv", ["head", "qkv.q", "qkv.in"]),
                             ("mlp", ["head", "mlp.down", "mlp.router"]),
                             ("all", ["head", "qkv.k", "mlp.up"])]:
        ctx = FTContext(registry=reg, scope=scope)
        for site in protected:
            assert ctx.protects(site), (scope, site)
    ctx = FTContext(registry=reg, scope="qkv")
    assert not ctx.protects("mlp.up")
    with pytest.raises(ValueError, match="ft_scope"):
        FTContext(registry=reg, scope="everything")


def test_pretuned_seed_cache_cold_hit(tmp_path, monkeypatch):
    """A cold process (empty user cache file) whose serving shapes are
    covered by the shipped interpret_cpu.json must warm WITHOUT a single
    sweep — the ROADMAP 'ship a pre-tuned cache' contract."""
    from repro.configs import get_smoke_config
    from repro.kernels import autotune
    from repro.models import get_model
    from repro.serve import ServeConfig, ServeEngine

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    cache = autotune.reset_cache(str(tmp_path / "at.json"))
    try:
        cfg = get_smoke_config("llama3.2-1b")
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg, max_seq=48)
        eng = ServeEngine(
            cfg, ServeConfig(max_batch=4, max_seq=48, ft_mode="entangle",
                             ft_M=4, ft_scope="all", blocks="auto"), params)
        assert cache.sweeps == 0, "cold warm swept despite pretuned cache"
        assert cache.hits > 0
        # warm covered head AND every in-model protected site (incl. the
        # v2 output-projection category)
        assert eng.census["head_gemm"]
        sites = {s for s, _ in eng.census["protected"]}
        assert {"qkv.q", "qkv.k", "qkv.v",
                "mlp.gate", "mlp.up", "mlp.down", "out.o"} <= sites
        # steady-state refill path: a chunked refill engine only ever
        # replays census'd [Bp, chunk] shapes, so its cold start must be
        # sweep-free off the same shipped cache too
        eng2 = ServeEngine(
            cfg, ServeConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                             refill=True, ft_mode="entangle", ft_M=4,
                             ft_scope="all", blocks="auto"), params)
        assert cache.sweeps == 0, \
            "refill-path chunk shapes missing from pretuned seed cache"
        assert eng2.plans.misses == 0
        # token-packed admission runs ONE [Rp, Cp] program whatever the
        # packing mix — its protected shapes (rows = token_budget) must
        # cold-hit off the same shipped cache too
        eng3 = ServeEngine(
            cfg, ServeConfig(max_batch=4, max_seq=48, prefill_chunk=8,
                             token_budget=16, refill=True,
                             ft_mode="entangle", ft_M=4,
                             ft_scope="all", blocks="auto"), params)
        assert cache.sweeps == 0, \
            "packed-engine shapes missing from pretuned seed cache"
        assert eng3.plans.misses == 0
    finally:
        autotune.reset_cache(None)
