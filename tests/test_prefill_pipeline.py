"""Bucketed, chunked batched prefill pipeline invariants.

  * bucket-padding bitwise equivalence: the bucketed batched admission
    (padded [Bp, T_bucket] prefill, any bucket mix, chunked or not)
    produces tokens bit-identical to the per-request batch-1 baseline
    (serve/reference.py) — including rolling-window attention caches and
    recurrent (Mamba) state, which only stay exact because prefill_chunk
    masks cache writes / gates state updates by per-row true lengths;
  * with ft_mode='entangle' a fail-stop injected during a chunked,
    bucketed prefill (and every decode step) rolls forward in-kernel:
    all generated tokens bit-identical to the healthy run, for every
    group r;
  * prompts longer than the largest bucket are rejected loudly at
    submit();
  * census records BUCKET shapes (admission rows, padded length), not raw
    prompt lengths;
  * chunked admission interleaves with decode: active slots keep decoding
    every step while a long prompt batch is being prefilled;
  * warm_autotune covers the prefill-admission head shape, so
    blocks='auto' never sweeps inside a traced prefill.
"""
import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import PerSlotEngine, Request, ServeConfig, ServeEngine

RNG = np.random.default_rng(7)
_PARAMS_CACHE: dict = {}


def _setup(arch: str, max_seq: int = 48):
    if arch not in _PARAMS_CACHE:
        cfg = get_smoke_config(arch)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0), cfg, max_seq=max_seq)
        _PARAMS_CACHE[arch] = (cfg, model, params)
    return _PARAMS_CACHE[arch]


def _ragged_prompts(cfg, lengths):
    return [RNG.integers(0, cfg.vocab_size, size=int(n)).astype(np.int32)
            for n in lengths]


def _run(engine_cls, cfg, scfg, params, prompts, max_new=4,
         failed_group=None):
    eng = engine_cls(cfg, scfg, params)
    for r, p in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=p.copy(), max_new=max_new))
    if engine_cls is ServeEngine:
        eng.run_to_completion(max_steps=500, failed_group=failed_group)
    else:
        eng.run_to_completion(max_steps=500)
    return {r.rid: np.asarray(r.out) for r in eng.done}, eng


# lengths spanning several buckets of the default geometric set for
# max_seq=48 -> (8, 16, 32, 48); 20/25 exceed recurrentgemma's smoke
# window (16), so bucket padding must not clobber the rolling buffer
LENGTHS = [3, 20, 7, 12, 25, 5, 9, 17]


@pytest.mark.parametrize("chunk", [0, 8])
@pytest.mark.parametrize(
    "arch", ["llama3.2-1b", "falcon-mamba-7b", "recurrentgemma-2b"])
def test_bucketed_prefill_bit_identical_to_per_request(arch, chunk):
    """Any bucket mix, chunked or whole-bucket: greedy outputs must match
    the per-request batch-1 admission baseline bitwise."""
    cfg, _, params = _setup(arch)
    prompts = _ragged_prompts(cfg, LENGTHS)
    ref, _ = _run(PerSlotEngine, cfg,
                  ServeConfig(max_batch=4, max_seq=48), params, prompts)
    out, eng = _run(ServeEngine, cfg,
                    ServeConfig(max_batch=4, max_seq=48,
                                prefill_chunk=chunk), params, prompts)
    assert set(ref) == set(out) == set(range(len(LENGTHS)))
    for r in ref:
        np.testing.assert_array_equal(
            ref[r], out[r], err_msg=f"{arch} chunk={chunk} rid={r} "
                                    f"len={LENGTHS[r]}")
    # admission actually batched: far fewer prefill dispatches than
    # requests when chunking is off (one call per bucket batch)
    if chunk == 0:
        assert eng.prefill_calls < len(LENGTHS)


def test_prefill_ft_failstop_bit_identical_all_groups():
    """ft_mode='entangle' + chunked bucketed prefill: a fail-stop injected
    on EVERY step (admission head projections included) in ANY single
    group leaves all generated tokens bit-identical to the healthy run."""
    cfg, _, params = _setup("llama3.2-1b")
    prompts = _ragged_prompts(cfg, LENGTHS)
    scfg = ServeConfig(max_batch=4, max_seq=48, ft_mode="entangle", ft_M=4,
                       prefill_chunk=8)
    healthy, eng = _run(ServeEngine, cfg, scfg, params, prompts)
    assert eng.census["prefill"], "admission never took the bucketed path"
    for fg in range(4):
        injected, _ = _run(ServeEngine, cfg, scfg, params, prompts,
                           failed_group=fg)
        for r in healthy:
            np.testing.assert_array_equal(
                healthy[r], injected[r],
                err_msg=f"failed_group={fg} rid={r}")


@pytest.mark.parametrize(
    "arch", ["llama3.2-1b", "falcon-mamba-7b", "recurrentgemma-2b",
             "deepseek-v2-lite-16b"])
def test_prefill_ft_scope_all_failstop_bit_identical(arch):
    """ft_scope='all' + CHUNKED bucketed admission: every QKV/MLP/output
    GEMM of every prefill chunk — and, for the MoE model, every grouped
    per-expert GEMM — runs entangled, and a fail-stop injected on every
    step in ANY single group rolls forward in-kernel: all generated
    tokens bit-identical to the healthy scope='all' run, for dense, ssm,
    hybrid and MoE models."""
    cfg, _, params = _setup(arch)
    prompts = _ragged_prompts(cfg, [3, 20, 7, 12, 5])
    scfg = ServeConfig(max_batch=4, max_seq=48, ft_mode="entangle", ft_M=4,
                       ft_scope="all", prefill_chunk=8)
    healthy, eng = _run(ServeEngine, cfg, scfg, params, prompts, max_new=2)
    assert eng.census["prefill"], "admission never took the bucketed path"
    assert set(healthy) == set(range(5))
    for fg in range(4):
        injected, _ = _run(ServeEngine, cfg, scfg, params, prompts,
                           max_new=2, failed_group=fg)
        for r in healthy:
            np.testing.assert_array_equal(
                healthy[r], injected[r],
                err_msg=f"{arch} failed_group={fg} rid={r}")


def test_warm_autotune_covers_protected_scope_shapes(tmp_path, monkeypatch):
    """blocks='auto' + ft_scope='all': startup warmup must pre-sweep EVERY
    in-model protected GEMM shape (decode and each chunk width) as well as
    the head shapes, so the in-jit resolution never sweeps inside a traced
    program — and the engine then serves a wave without error."""
    from repro.ft import group_rows

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    cfg, _, params = _setup("llama3.2-1b")
    eng = ServeEngine(cfg, ServeConfig(max_batch=4, max_seq=48,
                                       ft_mode="entangle", ft_M=4,
                                       ft_scope="all", prefill_chunk=8,
                                       blocks="auto"), params)
    D, V = eng._head_dims  # true dims; head_q is stored packed
    assert (4, 1, D, V) in eng.census["head_gemm"]
    shapes = eng.census["protected"]
    # decode: 4 rows -> 1 per group; chunk: Bp * 8 rows -> 8 per group
    hd = cfg.resolved_head_dim
    for rows in (4, 4 * 8):
        assert ("qkv.q", (4, group_rows(rows, 4), D,
                          cfg.n_heads * hd)) in shapes
        assert ("mlp.down", (4, group_rows(rows, 4), cfg.d_ff, D)) in shapes
    for r, p in enumerate(_ragged_prompts(cfg, [4, 9])):
        eng.submit(Request(rid=r, prompt=p, max_new=2))
    done = eng.run_to_completion(max_steps=100)
    assert len(done) == 2


def test_oversize_prompt_rejected_loudly():
    """A prompt longer than the largest configured bucket must raise at
    submit() (silently it would retrace per length or OOM the planner)."""
    cfg, _, params = _setup("llama3.2-1b")
    eng = ServeEngine(cfg, ServeConfig(max_batch=2, max_seq=48,
                                       prefill_buckets=(8, 16)), params)
    with pytest.raises(ValueError, match="bucket"):
        eng.submit(Request(rid=0, prompt=np.zeros(17, np.int32), max_new=2))
    # the default geometric set tops out at max_seq: same loud failure
    eng2 = ServeEngine(cfg, ServeConfig(max_batch=2, max_seq=48), params)
    with pytest.raises(ValueError, match="bucket"):
        eng2.submit(Request(rid=1, prompt=np.zeros(49, np.int32), max_new=1))


def test_census_records_bucket_shapes():
    """census['prefill'] keys are (admission rows, bucket) call shapes —
    raw prompt lengths (which would imply per-length retraces) never
    appear."""
    cfg, _, params = _setup("llama3.2-1b")
    out, eng = _run(ServeEngine, cfg, ServeConfig(max_batch=4, max_seq=48),
                    params, _ragged_prompts(cfg, [3, 5, 11, 20]))
    assert set(eng.census["prefill"]) == {(4, 8), (4, 16), (4, 32)}
    for (rows, bucket) in eng.census["prefill"]:
        assert bucket in eng.buckets and rows == 4


def test_chunked_admission_interleaves_with_decode():
    """While a long prompt batch is being prefilled chunk-by-chunk, active
    slots must still get their batched decode step every engine step —
    decode latency stays flat through admission."""
    cfg, _, params = _setup("llama3.2-1b")
    eng = ServeEngine(cfg, ServeConfig(max_batch=2, max_seq=48,
                                       prefill_chunk=8), params)
    eng.submit(Request(rid=0, prompt=_ragged_prompts(cfg, [5])[0],
                       max_new=12))
    eng.step()  # rid=0 admitted (bucket 8 = one chunk) and decoding
    assert eng.slots[0] is not None and eng.decode_calls == 1
    eng.submit(Request(rid=1, prompt=_ragged_prompts(cfg, [30])[0],
                       max_new=5))
    for s in range(4):  # bucket 32 / chunk 8 = 4 chunked steps
        toks_before = len(eng.slots[0]["toks"])
        eng.step()
        assert len(eng.slots[0]["toks"]) == toks_before + 1, \
            f"decode stalled during admission chunk {s}"
        admitted = any(s is not None and s["req"].rid == 1
                       for s in eng.slots)
        assert admitted == (s == 3), f"chunk {s}: admitted={admitted}"
    assert eng.prefill_calls == 1 + 4  # rid0: 1 chunk, rid1: 4 chunks


def test_warm_autotune_covers_prefill_shapes(tmp_path, monkeypatch):
    """blocks='auto': startup warmup must pre-sweep the admission head
    GEMM shape as well as the decode one, so the in-jit resolution is a
    pure cache hit (never a sweep inside a traced prefill)."""
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    cfg, _, params = _setup("llama3.2-1b")
    eng = ServeEngine(cfg, ServeConfig(max_batch=4, max_seq=48,
                                       ft_mode="entangle", ft_M=4,
                                       blocks="auto"), params)
    D, V = eng._head_dims  # true dims; head_q is stored packed
    assert (4, 1, D, V) in eng.census["head_gemm"]  # decode AND prefill
    # the warmed engine serves a wave without error (auto inside jit)
    for r, p in enumerate(_ragged_prompts(cfg, [4, 6, 9])):
        eng.submit(Request(rid=r, prompt=p, max_new=2))
    done = eng.run_to_completion(max_steps=100)
    assert len(done) == 3
