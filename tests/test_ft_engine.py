"""FT engine: all protection families x LSB ops x injected fail-stops, plus
SDC detection (paper Remark 4, implemented beyond-paper)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import FTConfig, entangle, make_plan, run_protected
from repro.core import sdc

RNG = np.random.default_rng(7)
M = 4


def _streams(n=96, lim=40):
    return jnp.asarray(RNG.integers(-lim, lim, size=(M, n)).astype(np.int32))


OPS_AND_KERNELS = [
    ("conv", lambda: jnp.asarray(RNG.integers(-20, 20, (9,)).astype(np.int32))),
    ("xcorr", lambda: jnp.asarray(RNG.integers(-20, 20, (9,)).astype(np.int32))),
    ("scale", lambda: jnp.int32(7)),
    ("add", lambda: jnp.int32(-13)),
    ("sub", lambda: jnp.int32(5)),
    ("dot", lambda: jnp.asarray(RNG.integers(-5, 5, (96,)).astype(np.int32))),
    ("permute", lambda: jnp.asarray(RNG.permutation(96))),
    ("identity", lambda: None),
]


@pytest.mark.parametrize("mode", ["entangle", "checksum", "mr"])
@pytest.mark.parametrize("opname,kern_fn", OPS_AND_KERNELS)
def test_recovery_all_ops_all_failures(mode, opname, kern_fn):
    c = _streams()
    g = kern_fn()
    ref, _ = run_protected(opname, c, g, FTConfig(mode="none", M=M))
    cfg = FTConfig(mode=mode, M=M)
    failures = list(range(M)) + [None] + ([M] if mode == "checksum" else [])
    for failed in failures:
        out, rep = run_protected(opname, c, g, cfg, failed=failed)
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(ref),
            err_msg=f"{mode}/{opname}/failed={failed}")
        assert rep.recovered


def test_unprotected_baseline_loses_data():
    c = _streams()
    cfg = FTConfig(mode="none", M=M)
    ref, _ = run_protected("scale", c, jnp.int32(3), cfg)
    out, rep = run_protected("scale", c, jnp.int32(3), cfg, failed=2)
    assert not rep.recovered
    assert not np.array_equal(np.asarray(out[2]), np.asarray(ref[2]))


def test_entangle_is_in_place_no_extra_streams():
    """Entanglement stores M streams in M slots (no checksum stream)."""
    plan = make_plan(M, 32)
    c = _streams()
    eps = entangle(c, plan)
    assert eps.shape == c.shape


def test_sdc_detection_guaranteed():
    plan = make_plan(M, 32)
    c = _streams(lim=1000)
    delta = entangle(c, plan)
    assert not np.asarray(sdc.detect(delta, plan)).any()
    for j in range(M):
        for mag in (1, 255, 1 << 15):
            bad = np.asarray(sdc.detect(delta.at[j, 17].add(mag), plan))
            assert bad[17] and bad.sum() == 1, (j, mag)


def test_sdc_localization_heuristic():
    plan = make_plan(M, 32)
    c = _streams(lim=1000)
    delta = entangle(c, plan)
    hits = 0
    for j in range(M):
        blame = np.asarray(sdc.localize(delta.at[j, 3].add(12345), plan))
        hits += int(blame[3] == j)
    assert hits >= 3  # heuristic: expect near-perfect on large corruption
