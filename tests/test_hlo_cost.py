"""Regression tests for the trip-count-aware HLO cost model — the roofline's
foundation (launch/hlo_cost.py)."""
import jax
import jax.numpy as jnp
from jax import lax

from repro.launch.hlo_cost import HloCostModel, analyze_text


def _compile_text(f, *specs):
    return jax.jit(f).lower(*specs).compile().as_text()


def test_scan_trip_count_multiplies_flops():
    """cost_analysis counts while bodies once; our model must multiply."""

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), 0

        y, _ = lax.scan(body, x, None, length=10)
        return y

    s = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    res = analyze_text(_compile_text(f, s, s))
    expect = 10 * 2 * 128**3
    assert expect <= res["flops_per_device"] < expect * 1.25


def test_nested_scan_trip_counts_compose():
    def f(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, 0

            y, _ = lax.scan(inner, c, None, length=4)
            return y, 0

        y, _ = lax.scan(outer, x, None, length=5)
        return y

    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    res = analyze_text(_compile_text(f, s, s))
    expect = 20 * 2 * 64**3
    assert expect <= res["flops_per_device"] < expect * 1.3


def test_plain_matmul_flops_exact():
    def f(a, b):
        return a @ b

    s = jax.ShapeDtypeStruct((256, 128), jnp.float32)
    t = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    res = analyze_text(_compile_text(f, s, t))
    assert abs(res["flops_per_device"] - 2 * 256 * 128 * 64) < 1e5


def test_scan_slice_bytes_are_windowed():
    """Per-step dynamic-slice reads must be charged the window, not the
    full stacked operand x trip count."""

    def f(xs):
        def body(c, x):
            return c + jnp.sum(x), 0

        y, _ = lax.scan(body, jnp.float32(0), xs)
        return y

    s = jax.ShapeDtypeStruct((1000, 64), jnp.float32)
    res = analyze_text(_compile_text(f, s))
    full = 1000 * 64 * 4
    # total reads ~ one pass over xs (+constants), NOT trips x full array
    assert res["bytes_per_device"] < 20 * full


def test_collective_bytes_and_counts():
    """all-reduce operand bytes are attributed (2-device subprocess-free:
    use a 1-device mesh psum — SPMD still emits the collective op when the
    axis exists in shard_map)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("d",))

    def f(x):
        return shard_map(lambda a: lax.psum(a, "d"), mesh=mesh,
                         in_specs=P("d"), out_specs=P())(x)

    s = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    text = _compile_text(f, s)
    res = analyze_text(text)
    if "all-reduce" in text:  # 1-device psum may fold away; only assert if emitted
        assert res["collective_bytes_per_device"] >= 8 * 128 * 4
        assert res["collective_counts"].get("all-reduce", 0) >= 1


def test_parser_handles_tuple_types_and_roots():
    def f(x):
        def body(carry, _):
            a, b = carry
            return (a @ b, b), None

        (a, _), _ = lax.scan(body, (x, x), None, length=3)
        return a

    s = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    text = _compile_text(f, s)
    m = HloCostModel(text)
    assert m.entry in m.computations
    cost = m.entry_cost()
    assert cost.flops >= 3 * 2 * 32**3
