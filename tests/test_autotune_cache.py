"""Autotune cache-loader hardening.

The JSON winner cache is an *optimization*: a corrupted or partially
written ``REPRO_AUTOTUNE_CACHE`` file (interrupted process, disk full,
hand edit) must degrade to the shipped pre-tuned seed cache — or a fresh
sweep — with a warning, never crash startup. Covered:

  * corrupt-file: truncated/invalid JSON is ignored with a RuntimeWarning
    and lookups fall through to the pretuned seed;
  * wrong-structure: a JSON file that is not an object, and entries whose
    values are not block dicts, are skipped per-entry (one bad key cannot
    poison the valid winners beside it);
  * missing-key: a key absent from the user cache falls through to the
    pretuned seed, and an unknown key sweeps and persists;
  * precedence: a user-cache winner SHADOWS the pretuned seed for the
    same key (user-tuned always wins).
"""
import json
import pathlib

import pytest

from repro.kernels import autotune


@pytest.fixture
def pretuned_dir(tmp_path, monkeypatch):
    """Point the shipped-seed loader at a controlled directory."""
    d = tmp_path / "pretuned"
    d.mkdir()
    monkeypatch.setattr(autotune, "PRETUNED_DIR", d)
    yield d
    autotune.reset_cache(None)


def _seed(d: pathlib.Path, key: str, blocks: dict):
    (d / "interpret_cpu.json").write_text(json.dumps(
        {"_meta": {"version": 1}, key: blocks}))


KEY = "entangled_matmul|4x8x64x32|interpret_cpu|l8,dualword,fused"


def test_corrupt_user_cache_falls_back_to_pretuned(tmp_path, pretuned_dir):
    _seed(pretuned_dir, KEY, {"bb": 8, "bn": 32, "bk": 64})
    user = tmp_path / "at.json"
    user.write_text('{"entangled_matmul|4x8x64x32|interp')  # torn write
    cache = autotune.AutotuneCache(str(user))
    with pytest.warns(RuntimeWarning, match="not valid JSON"):
        got = cache.get(KEY)
    assert got == {"bb": 8, "bn": 32, "bk": 64}, \
        "corrupt user cache must fall back to the pretuned seed"
    assert cache.hits == 1


def test_wrong_structure_skips_bad_entries(tmp_path, pretuned_dir):
    _seed(pretuned_dir, KEY, {"bb": 8, "bn": 32, "bk": 64})
    user = tmp_path / "at.json"
    user.write_text(json.dumps({
        "good|1x2|interpret_cpu|": {"bb": 16},
        "bad1": "not-a-dict",
        "bad2": ["nor", "a", "dict"],
        "bad3": {"bb": "NaNish-garbage"},
    }))
    cache = autotune.AutotuneCache(str(user))
    with pytest.warns(RuntimeWarning, match="malformed"):
        assert cache.get("good|1x2|interpret_cpu|") == {"bb": 16}
    assert cache.get("bad1") is None
    assert cache.get("bad3") is None
    # the pretuned seed is still intact behind the half-bad user cache
    assert cache.get(KEY) == {"bb": 8, "bn": 32, "bk": 64}

    top_level_list = tmp_path / "list.json"
    top_level_list.write_text(json.dumps(["not", "an", "object"]))
    cache2 = autotune.AutotuneCache(str(top_level_list))
    with pytest.warns(RuntimeWarning, match="JSON object"):
        assert cache2.get(KEY) == {"bb": 8, "bn": 32, "bk": 64}


def test_missing_key_sweeps_and_persists(tmp_path, pretuned_dir):
    """A key in neither cache sweeps once and lands in the user file."""
    user = tmp_path / "at.json"
    cache = autotune.AutotuneCache(str(user))
    ticks = []

    def bench(blocks):
        def thunk():
            ticks.append(blocks["block_n"])
            return 0
        return thunk

    won = autotune.tune("entangle", (4, 64), "interpret_cpu", bench,
                        candidates=[{"block_n": 128}, {"block_n": 256}],
                        cache=cache)
    assert won["block_n"] in (128, 256) and ticks
    assert cache.sweeps == 1
    on_disk = json.loads(user.read_text())
    key = autotune.cache_key("entangle", (4, 64), "interpret_cpu")
    assert on_disk[key] == won
    # second resolve: pure hit, no sweep
    n = len(ticks)
    assert autotune.tune("entangle", (4, 64), "interpret_cpu", bench,
                         cache=cache) == won
    assert len(ticks) == n and cache.sweeps == 1


def test_stale_backend_namespace_ignored(tmp_path, pretuned_dir):
    """Keys from a pre-v2 cache (backend tag 'interpret') or an
    unregistered port can never match a lookup in this process: they are
    dropped at load with one aggregate warning instead of lingering in
    the in-memory cache and inflating stats."""
    old_key = "entangled_matmul|4x8x64x32|interpret|l8,dualword,fused"
    user = tmp_path / "at.json"
    user.write_text(json.dumps({
        old_key: {"bb": 8, "bn": 32, "bk": 64},
        "entangled_matmul|4x8x64x32|some_unloaded_port|": {"bb": 16},
        KEY: {"bb": 128, "bn": 64, "bk": 32},
    }))
    cache = autotune.AutotuneCache(str(user))
    with pytest.warns(RuntimeWarning, match="not registered"):
        assert cache.get(KEY) == {"bb": 128, "bn": 64, "bk": 32}
    assert cache.get(old_key) is None
    assert old_key not in cache._mem


def test_user_cache_shadows_pretuned(tmp_path, pretuned_dir):
    _seed(pretuned_dir, KEY, {"bb": 8, "bn": 32, "bk": 64})
    user = tmp_path / "at.json"
    user.write_text(json.dumps({KEY: {"bb": 128, "bn": 64, "bk": 32}}))
    cache = autotune.AutotuneCache(str(user))
    assert cache.get(KEY) == {"bb": 128, "bn": 64, "bk": 32}, \
        "user-tuned winners must take precedence over the shipped seed"
