"""Beyond-paper: entangled integer GEMM overhead (the paper analyzes GEMM
cost in Sec. IV but measures only convolution). Also measures the checksum
GEMM baseline, and reports the fused-vs-separate HBM bytes model per size
(the codec traffic the fused Pallas kernel removes from the critical
bandwidth path). Streams = M row-blocks of the left matrix."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fusion_bytes_model, time_call
from repro.core.entangle import disentangle, entangle
from repro.core.plan import make_plan


@jax.jit
def _plain(c, g):
    return jnp.einsum("mbk,kn->mbn", c, g)


def _make_entangled(plan):
    @jax.jit
    def run(c, g):
        eps = entangle(c.astype(jnp.int32), plan)
        delta = jnp.einsum("mbk,kn->mbn", eps.astype(c.dtype), g)
        return disentangle(delta.astype(jnp.int32), plan)

    return run


@jax.jit
def _checksum(c, g):
    r = jnp.sum(c, axis=0, keepdims=True)
    return jnp.einsum("mbk,kn->mbn", jnp.concatenate([c, r], 0), g)


def run(emit, sizes=(128, 256, 512)):
    rng = np.random.default_rng(1)
    for M in (4, 8):
        plan = make_plan(M, 32)
        for N in sizes:
            lim = max(int(np.sqrt(plan.max_output_magnitude / N)) // 2, 2)
            c = jnp.asarray(
                rng.integers(-lim, lim, size=(M, N, N)).astype(np.float64))
            g = jnp.asarray(rng.integers(-lim, lim, size=(N, N)).astype(np.float64))
            ent = _make_entangled(plan)
            want = np.asarray(_plain(c, g)).astype(np.int64)
            got = np.asarray(ent(c, g)).astype(np.int64)
            assert np.array_equal(want, got), (M, N)
            t0 = time_call(_plain, c, g)
            t1 = time_call(ent, c, g)
            t2 = time_call(_checksum, c, g)
            bts = fusion_bytes_model(M, N, N, N)
            emit(f"gemm_M{M}_N{N}", t0 * 1e6,
                 f"overhead_entangle_pct={(t1/t0-1)*100:.1f};"
                 f"overhead_checksum_pct={(t2/t0-1)*100:.1f};"
                 f"hbm_bytes_fused={bts['fused']};"
                 f"hbm_bytes_three_pass={bts['three_pass']};"
                 f"codec_bytes_removed_pct="
                 f"{(1 - bts['fused']/bts['three_pass'])*100:.0f}")
