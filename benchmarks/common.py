"""Shared benchmark utilities: timed jit'd calls, CSV emission."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 1, repeats: int = 3) -> float:
    """Best-of-N wall time (seconds) of a jit'd call, sync'd."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
