"""Shared benchmark utilities: timed jit'd calls, CSV emission, and the
BENCH_*.json recorder (the artifact CI uploads to track the overhead
trajectory across PRs)."""
from __future__ import annotations

import json
import pathlib
import time

import jax

_RECORDS: list[dict] = []


def time_call(fn, *args, warmup: int = 1, repeats: int = 3) -> float:
    """Best-of-N wall time (seconds) of a jit'd call, sync'd."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def fusion_bytes_model(M: int, B: int, K: int, N: int) -> dict[str, int]:
    """Ideal HBM bytes moved by each entangled-GEMM schedule (int32).

    fused: one pallas_call (entangle-on-load, extract-at-flush); two_pass:
    fused GEMM + separate disentangle sweep; three_pass: entangle sweep +
    GEMM + disentangle sweep. Pure arithmetic — lives here so XLA-only
    benchmarks can report it without importing the Pallas kernel stack.
    """
    gemm = M * B * K + K * N + M * B * N
    return {
        "fused": 4 * gemm,
        "two_pass": 4 * (gemm + 2 * M * B * N),
        "three_pass": 4 * (gemm + 2 * M * B * K + 2 * M * B * N),
    }


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
    _RECORDS.append(
        {"name": name, "us_per_call": round(us_per_call, 1),
         "derived": derived}
    )


def write_bench_json(tag: str, extra_meta: dict | None = None) -> pathlib.Path:
    """Dump everything emitted so far to ./BENCH_<tag>.json."""
    path = pathlib.Path.cwd() / f"BENCH_{tag}.json"
    payload = {
        "meta": {
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            **(extra_meta or {}),
        },
        "records": _RECORDS,
    }
    path.write_text(json.dumps(payload, indent=1))
    return path
