"""Benchmark harness — one module per paper table/figure.

  table1_bitwidth      paper Table I (l, k, bitwidths; exact reproduction)
  complexity_model     paper Sec. IV op-count model + claims
  fig2_conv_throughput paper Fig. 2 (conv throughput, NE vs checksum)
  gemm_overhead        Sec. IV GEMM cost, measured (beyond-paper)
  kernel_micro         codec bandwidth + fused-vs-separate ledger
  serve_throughput     batched vs per-slot engine tok/s + entangled-head
                       overhead, plus the prompt-heavy admission wave
                       (bucketed batched prefill >= 2x per-request gate)
                       (writes BENCH_serve.json)
  roofline_report      dry-run three-term roofline summary (if artifacts)

Prints ``name,us_per_call,derived`` CSV and writes every record to
``BENCH_<mode>.json`` (the artifact CI uploads). ``--quick`` shrinks
problem sizes; ``--smoke`` is the CI mode — the validation-bearing subsets
(table1, complexity, gemm, micro incl. the fused-codec ledger) at small
sizes, suitable for CPU interpret mode.
"""
from __future__ import annotations

import argparse
import sys

import jax

jax.config.update("jax_enable_x64", True)  # exact f64 conv (paper uses
# ippsConv_64f); benchmarks run in their own process, tests are unaffected.

from benchmarks.common import emit, write_bench_json  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: validation subsets at small sizes")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    ok = True
    quick = args.quick or args.smoke

    def want(name):
        if args.only:
            return name in args.only.split(",")
        if args.smoke:
            return name in ("table1", "complexity", "gemm", "micro", "serve")
        return True

    if want("table1"):
        from benchmarks import table1_bitwidth

        ok &= table1_bitwidth.run(emit)
    if want("complexity"):
        from benchmarks import complexity_model

        ok &= complexity_model.run(emit)
    if want("fig2"):
        from benchmarks import fig2_conv_throughput

        n = 50_000 if quick else 200_000
        ks = (100, 1000) if quick else (100, 1000, 4500)
        fig2_conv_throughput.run(emit, n_in=n, kernel_sizes=ks)
    if want("gemm"):
        from benchmarks import gemm_overhead

        gemm_overhead.run(emit, sizes=(128, 256) if quick else (128, 256, 512))
    if want("micro"):
        from benchmarks import kernel_micro

        fusion_sizes = (
            ((4, 64, 64, 64), (4, 128, 64, 128)) if quick else None
        )
        ok &= kernel_micro.run(emit, n=1 << (18 if quick else 20),
                               fusion_sizes=fusion_sizes)
    if want("serve"):
        from benchmarks import serve_throughput

        # not shrunk under --quick/--smoke: waves shorter than ~16x8 tokens
        # are dispatch-noise-dominated and make the 2x gate flaky
        ok &= serve_throughput.run(emit)
    if want("roofline"):
        from benchmarks import roofline_report

        roofline_report.run(emit)

    mode = "smoke" if args.smoke else ("quick" if args.quick else "full")
    if args.only:  # a subset run must not masquerade as a full artifact
        mode = "only-" + args.only.replace(",", "-")
    path = write_bench_json(mode, {"mode": mode, "ok": bool(ok)})
    print(f"[bench] wrote {path}", file=sys.stderr)

    if not ok:
        print("benchmark_validation,0.0,FAILED", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
