"""Benchmark harness — one module per paper table/figure.

  table1_bitwidth      paper Table I (l, k, bitwidths; exact reproduction)
  complexity_model     paper Sec. IV op-count model + claims
  fig2_conv_throughput paper Fig. 2 (conv throughput, NE vs checksum)
  gemm_overhead        Sec. IV GEMM cost, measured (beyond-paper)
  kernel_micro         codec bandwidth microbenches
  roofline_report      dry-run three-term roofline summary (if artifacts)

Prints ``name,us_per_call,derived`` CSV. ``--quick`` shrinks problem sizes.
"""
from __future__ import annotations

import argparse
import sys

import jax

jax.config.update("jax_enable_x64", True)  # exact f64 conv (paper uses
# ippsConv_64f); benchmarks run in their own process, tests are unaffected.

from benchmarks.common import emit  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    ok = True

    def want(name):
        return not args.only or name in args.only.split(",")

    if want("table1"):
        from benchmarks import table1_bitwidth

        ok &= table1_bitwidth.run(emit)
    if want("complexity"):
        from benchmarks import complexity_model

        ok &= complexity_model.run(emit)
    if want("fig2"):
        from benchmarks import fig2_conv_throughput

        n = 50_000 if args.quick else 200_000
        ks = (100, 1000) if args.quick else (100, 1000, 4500)
        fig2_conv_throughput.run(emit, n_in=n, kernel_sizes=ks)
    if want("gemm"):
        from benchmarks import gemm_overhead

        gemm_overhead.run(emit, sizes=(128, 256) if args.quick else (128, 256, 512))
    if want("micro"):
        from benchmarks import kernel_micro

        kernel_micro.run(emit, n=1 << (18 if args.quick else 20))
    if want("roofline"):
        from benchmarks import roofline_report

        roofline_report.run(emit)

    if not ok:
        print("benchmark_validation,0.0,FAILED", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
