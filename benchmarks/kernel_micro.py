"""Microbenchmarks of the core codec ops (jnp/XLA path — the Pallas kernels
target TPU and are validated via interpret mode in tests, not timed here)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core.entangle import disentangle, entangle
from repro.core.plan import make_plan


def run(emit, n: int = 1 << 20):
    rng = np.random.default_rng(2)
    for M, w in ((3, 32), (8, 32), (4, 16)):
        plan = make_plan(M, w)
        D = plan.max_output_magnitude
        c = jnp.asarray(rng.integers(-D // 2, D // 2, size=(M, n)).astype(np.int32))
        ent = jax.jit(lambda x, p=plan: entangle(x, p))
        dis = jax.jit(lambda x, p=plan: disentangle(x, p, failed=1))
        t_e = time_call(ent, c)
        delta = ent(c)
        t_d = time_call(dis, delta)
        gbps_e = M * n * 4 / t_e / 1e9
        gbps_d = M * n * 4 / t_d / 1e9
        emit(f"codec_M{M}_w{w}", t_e * 1e6,
             f"entangle_GBps={gbps_e:.2f};disentangle_GBps={gbps_d:.2f};"
             f"temp={plan.temp}")
