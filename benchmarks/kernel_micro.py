"""Microbenchmarks of the codec ops, plus the fused-vs-separate ledger.

Two sections:

  codec_*   entangle/disentangle bandwidth on the jnp/XLA path (the Pallas
            kernels target TPU and are validated via interpret mode).

  fusion_*  the tentpole measurement: entangle -> GEMM -> extract as ONE
            fused pallas_call vs the separate-pass schedules, with the HBM
            bytes-moved model for each. The paper's 1.8-2.8% overhead claim
            requires the codec to ride the compute pass; the bytes model
            makes the difference auditable:

              fused      in: M*B*K + K*N      out: M*B*N
              two-pass   fused GEMM (entangle-on-load) + separate
                         disentangle sweep:          + 2*M*B*N
              three-pass entangle sweep + GEMM + disentangle sweep:
                         + 2*M*B*K + 2*M*B*N

            run() validates ratio(three-pass/fused) >= 2 and reports
            wall-times on the current backend (interpret mode off-TPU).

  packed_*  the int8-packing ledger: protected weights stored 4 int8
            lanes per int32 word (unpacked container: 4*K*N bytes,
            packed: 4*ceil(K/4)*N — true int8 bytes). run() validates
            the packed fused kernel is bit-equal to the unpacked one
            (healthy and failed) and that the weight-bytes ratio is
            >= 3x (exactly 4x whenever 4 | K).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import fusion_bytes_model, time_call
from repro.core.entangle import disentangle, entangle
from repro.core.plan import make_plan
from repro.kernels import ops as kops
from repro.kernels.codec import pack_int8


def _codec_section(emit, n: int):
    rng = np.random.default_rng(2)
    for M, w in ((3, 32), (8, 32), (4, 16)):
        plan = make_plan(M, w)
        D = plan.max_output_magnitude
        c = jnp.asarray(rng.integers(-D // 2, D // 2, size=(M, n)).astype(np.int32))
        ent = jax.jit(lambda x, p=plan: entangle(x, p))
        dis = jax.jit(lambda x, p=plan: disentangle(x, p, failed=1))
        t_e = time_call(ent, c)
        delta = ent(c)
        t_d = time_call(dis, delta)
        gbps_e = M * n * 4 / t_e / 1e9
        gbps_d = M * n * 4 / t_d / 1e9
        emit(f"codec_M{M}_w{w}", t_e * 1e6,
             f"entangle_GBps={gbps_e:.2f};disentangle_GBps={gbps_d:.2f};"
             f"temp={plan.temp}")


def _fusion_section(emit, sizes) -> bool:
    rng = np.random.default_rng(4)
    ok = True
    for M, B, K, N in sizes:
        plan = make_plan(M, 32)
        lim = max(int(np.sqrt(plan.max_output_magnitude / K)) // 2, 1)
        c = jnp.asarray(rng.integers(-lim, lim, size=(M, B, K)).astype(np.int32))
        g = jnp.asarray(rng.integers(-lim, lim, size=(K, N)).astype(np.int32))
        bl = {"bb": min(64, B), "bn": min(64, N), "bk": min(64, K)}

        fused = lambda: kops.entangled_matmul(
            c, g, plan, fuse_epilogue=True, blocks=bl)
        two_pass = lambda: kops.disentangle(
            kops.entangled_matmul(c, g, plan, blocks=bl), plan)
        # three-pass: separate entangle sweep, GEMM, separate extract sweep
        three_pass = lambda: kops.disentangle(
            jnp.einsum("mbk,kn->mbn", kops.entangle(c, plan),
                       g).astype(jnp.int32), plan)

        np.testing.assert_array_equal(  # same results before timing them
            np.asarray(fused()), np.asarray(two_pass()))
        np.testing.assert_array_equal(
            np.asarray(fused()), np.asarray(three_pass()))

        t_f = time_call(fused)
        t_2 = time_call(two_pass)
        t_3 = time_call(three_pass)
        bts = fusion_bytes_model(M, B, K, N)
        ratio3 = bts["three_pass"] / bts["fused"]
        ok &= ratio3 >= 2.0
        emit(
            f"fusion_M{M}_B{B}_K{K}_N{N}", t_f * 1e6,
            f"t_two_pass_us={t_2 * 1e6:.1f};t_three_pass_us={t_3 * 1e6:.1f};"
            f"speedup_vs_three_pass={t_3 / t_f:.2f};"
            f"hbm_bytes_fused={bts['fused']};"
            f"hbm_bytes_two_pass={bts['two_pass']};"
            f"hbm_bytes_three_pass={bts['three_pass']};"
            f"bytes_ratio_three_over_fused={ratio3:.2f}",
        )
    return ok


def _packed_section(emit, sizes) -> bool:
    """Packed-int8 weight kernels: bit-equality vs the int32-container
    path, wall-times, and the weight-bytes ledger (gate: >= 3x fewer)."""
    rng = np.random.default_rng(6)
    ok = True
    for M, B, K, N in sizes:
        plan = make_plan(M, 32)
        lim = max(plan.max_output_magnitude // (K * 127), 1)
        c = jnp.asarray(rng.integers(-lim, lim, size=(M, B, K)).astype(np.int32))
        g = jnp.asarray(rng.integers(-127, 128, size=(K, N)).astype(np.int32))
        gp = pack_int8(g, axis=0)
        bl = {"bb": min(64, B), "bn": min(64, N), "bk": min(64, K)}

        unpacked = lambda f=None: kops.entangled_matmul(
            c, g, plan, fuse_epilogue=True, failed=f, blocks=bl)
        packed = lambda f=None: kops.entangled_matmul(
            c, gp, plan, fuse_epilogue=True, failed=f, packed=True,
            blocks=bl)

        for f in (None, 1):  # bit-equal before timing, healthy and failed
            np.testing.assert_array_equal(
                np.asarray(packed(f)), np.asarray(unpacked(f)))

        t_u = time_call(unpacked)
        t_p = time_call(packed)
        w_unpacked = 4 * K * N  # int32 container holding int8 values
        w_packed = 4 * (-(-K // 4)) * N  # 4 lanes per word: true int8 bytes
        ratio = w_unpacked / w_packed
        ok &= ratio >= 3.0
        emit(
            f"packed_M{M}_B{B}_K{K}_N{N}", t_p * 1e6,
            f"t_unpacked_us={t_u * 1e6:.1f};"
            f"weight_bytes_unpacked={w_unpacked};"
            f"weight_bytes_packed={w_packed};"
            f"weight_bytes_ratio={ratio:.2f} (gate >= 3x: "
            f"{'PASS' if ratio >= 3.0 else 'FAIL'})",
        )
    return ok


def run(emit, n: int = 1 << 20, fusion_sizes=None) -> bool:
    _codec_section(emit, n)
    if fusion_sizes is None:
        fusion_sizes = ((4, 128, 128, 128), (4, 256, 128, 256),
                        (8, 128, 128, 128))
    ok = _fusion_section(emit, fusion_sizes)
    ok &= _packed_section(emit, fusion_sizes)
    return ok
