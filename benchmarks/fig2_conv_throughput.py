"""Paper Fig. 2: throughput of M-stream integer convolution —
conventional (failure-intolerant) vs proposed (numerical entanglement) vs
checksum-based, for M in {3, 8} and several kernel sizes.

Matches the paper's setup: 32-bit integer streams, convolution executed in
f64 (the paper uses IPP ippsConv_64f — exact for |values| < 2^53), N_in
samples per stream. The reproduced CLAIMS are the overhead ratios:
entanglement ~ few %, checksum ~ +1/M extra compute (16-38% measured in the
paper); absolute throughput differs (XLA/CPU here vs AVX2/IPP there).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import time_call
from repro.core.entangle import disentangle, entangle
from repro.core.plan import make_plan


def _conv_f64(x, g):
    return jnp.convolve(x, g, mode="full", precision="highest")


@functools.partial(jax.jit, static_argnames=())
def _conventional(c, g):
    return jax.vmap(lambda x: _conv_f64(x, g))(c)


def _make_entangled(plan):
    @jax.jit
    def run(c, g):
        eps = entangle(c, plan).astype(jnp.float64)
        delta = jax.vmap(lambda x: _conv_f64(x, g))(eps)
        return disentangle(delta.astype(jnp.int32), plan)

    return run


@jax.jit
def _checksum(c, g):
    r = jnp.sum(c, axis=0, keepdims=True)
    cr = jnp.concatenate([c, r], axis=0).astype(jnp.float64)
    return jax.vmap(lambda x: _conv_f64(x, g))(cr)


def run(emit, n_in: int = 200_000, kernel_sizes=(100, 1000, 4500)):
    assert jax.config.jax_enable_x64, "fig2 needs x64 (exact f64 conv)"
    rng = np.random.default_rng(0)
    results = {}
    for M in (3, 8):
        plan = make_plan(M, 32)
        # inputs sized so conv outputs respect the eq. (13) range contract
        lim = max(plan.max_output_magnitude // (max(kernel_sizes) * 4) - 1, 2)
        lim = min(lim, 1 << 12)
        c64 = rng.integers(-lim, lim, size=(M, n_in)).astype(np.int32)
        c = jnp.asarray(c64)
        cf = jnp.asarray(c64.astype(np.float64))
        ent = _make_entangled(plan)
        for nk in kernel_sizes:
            g = jnp.asarray(rng.integers(-4, 4, size=nk).astype(np.float64))
            # correctness: recovered == conventional (outside the timing)
            want = np.asarray(_conventional(cf, g)).astype(np.int64)
            got = np.asarray(ent(c, g)).astype(np.int64)
            assert np.array_equal(want, got), (M, nk)
            t_conv = time_call(_conventional, cf, g)
            t_ent = time_call(ent, c, g)
            t_cs = time_call(_checksum, c, g)
            thr = M * n_in / t_conv / 1e6  # Msamples/s
            ov_ent = (t_ent / t_conv - 1) * 100
            ov_cs = (t_cs / t_conv - 1) * 100
            results[(M, nk)] = (ov_ent, ov_cs)
            emit(
                f"fig2_M{M}_k{nk}", t_conv * 1e6,
                f"thr_conv_Msps={thr:.1f};overhead_entangle_pct={ov_ent:.1f};"
                f"overhead_checksum_pct={ov_cs:.1f}",
            )
    # paper claim: NE overhead an order of magnitude below checksum
    mean_ent = np.mean([v[0] for v in results.values()])
    mean_cs = np.mean([v[1] for v in results.values()])
    emit("fig2_summary", 0.0,
         f"mean_entangle_pct={mean_ent:.2f};mean_checksum_pct={mean_cs:.2f};"
         f"ratio={mean_cs/max(mean_ent,1e-9):.1f}x")
    return results
