"""Roofline reader: summarizes dry-run artifacts into the three-term model
(compute / memory / collective seconds per step on TPU v5e). Heavy parsing
lives in repro.launch.roofline; this benchmark emits the per-cell summary as
CSV if artifacts exist (run `python -m repro.launch.dryrun` first)."""
from __future__ import annotations

import json
import pathlib

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts"


def run(emit):
    rl = ART / "roofline" / "roofline.json"
    if not rl.exists():
        emit("roofline", 0.0, "missing;run=python -m repro.launch.roofline")
        return
    rows = json.loads(rl.read_text())
    for r in rows:
        emit(
            f"roofline_{r['arch']}_{r['cell']}", 0.0,
            f"compute_s={r['compute_s']:.2e};memory_s={r['memory_s']:.2e};"
            f"collective_s={r['collective_s']:.2e};bound={r['bound']};"
            f"useful_flops_frac={r['useful_frac']:.3f}",
        )
