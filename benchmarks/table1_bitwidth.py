"""Paper Table I: (l, k) and supported output bitwidth vs M, proposed vs
checksum-based, w=32. Validation: every row must match the paper exactly."""
from __future__ import annotations

from repro.core.plan import checksum_output_bits, make_plan, plan_lk

PAPER_TABLE_I = {
    3: (11, 10, 21, 30), 4: (8, 8, 24, 30), 5: (7, 4, 25, 29),
    8: (4, 4, 28, 29), 11: (3, 2, 29, 28), 16: (2, 2, 30, 28),
    32: (1, 1, 31, 27),
}


def run(emit):
    mismatches = 0
    for M, (l_p, k_p, bits_p, cs_p) in PAPER_TABLE_I.items():
        l, k = plan_lk(M, 32)
        plan = make_plan(M, 32)
        cs = checksum_output_bits(M, 32)
        ok = (l, k, plan.output_bits, cs) == (l_p, k_p, bits_p, cs_p)
        mismatches += not ok
        emit(
            f"table1_M{M}", 0.0,
            f"l={l};k={k};bits={plan.output_bits};checksum_bits={cs};"
            f"paper_match={'yes' if ok else 'NO'};"
            f"tight_bound={plan.max_output_magnitude_tight}",
        )
    emit("table1_summary", 0.0,
         f"rows=7;mismatches={mismatches};"
         f"claim=proposed_beats_checksum_bits_for_M_ge_11="
         f"{plan_ge11_wins()}")
    return mismatches == 0


def plan_ge11_wins() -> bool:
    for M in (11, 16, 32):
        if make_plan(M, 32).output_bits <= checksum_output_bits(M, 32):
            return False
    return True
