"""Serving throughput: batched continuous-batching engine vs the per-slot
baseline, with the entangled-head overhead — writes ``BENCH_serve.json``.

Measures steady-state tokens/s (second wave on a warm engine, so jit
compilation is amortized like a long-running server) for:

  * ``serve_per_slot``    — PerSlotEngine, one batch-1 decode per slot/step
  * ``serve_batched``     — ServeEngine, ONE jitted decode per step
  * ``serve_batched_ft``  — ServeEngine with the fused entangled int8 head
                            GEMM on every decode step (ft_mode='entangle',
                            ft_scope='head')
  * ``serve_batched_ft_all`` — ft_scope='all': EVERY hot-path projection
                            (QKV, MLP up/down, head) runs entangled, with
                            the defaults on — weights int8-PACKED
                            4-per-word (kernels unpack on load) and fanout
                            site groups sharing one codec pass
  * ``serve_batched_ft_all_unpacked`` — same scope with ``ft_packed=False,
                            ft_chain=False``: the legacy int32-container /
                            per-site-codec path, kept as the A/B baseline

plus a PROMPT-HEAVY admission wave (max_new=1, so the wave is pure
prefill) for:

  * ``prefill_per_request``  — PerSlotEngine, one batch-1 prefill per admit
  * ``prefill_bucketed``     — ServeEngine bucketed batched prefill
  * ``prefill_bucketed_ft``  — same, entangled first-token projection
  * ``prefill_bucketed_ft_all`` — same, every admission-chunk GEMM entangled

Derived records: ``serve_speedup`` / ``prefill_speedup`` (batched vs
per-request, both >= 2x acceptance gates), per-scope ``ft_overhead_pct``
records — ``serve_ft_overhead_pct`` (scope=head) /
``serve_ft_overhead_pct_all`` (scope=all, packed+fanout defaults) /
``serve_ft_overhead_pct_all_packed`` (alias of the same measurement, the
record CI compares against ``..._all_unpacked`` on real backends — on
interpret CPU the unpack is simulated as extra compute while the 4x HBM
byte cut it buys is free, so there the A/B is informational and the
packed win is gated through the kernel_micro weight-bytes ledger) /
``serve_ft_overhead_pct_all_unpacked`` (the legacy A/B baseline), and the
prefill twins — and
``ft_coverage`` records asserting which protected-site CATEGORIES the
scope=all engines actually compiled plans for: ``serve_ft_coverage_all``
(dense arch: head/qkv/mlp/out) and ``serve_ft_coverage_moe`` (a
census-only MoE engine: + the grouped per-expert ``moe`` category). Since
the v2 redesign ``ft_scope='all'`` must genuinely cover everything, so CI
gates on these records. The CPU numbers run the Pallas kernels in
interpret mode — the FT overhead % here is an upper bound; the paper's
1.8-2.8% band is the compiled-TPU target tracked in ROADMAP.md.

Steady-state latency (open-loop): a seeded Poisson arrival trace of mixed
short/long prompts is replayed against TWO engines on a VIRTUAL clock
(1 unit per engine step — deterministic, immune to interpret-CPU wall
noise): mid-flight refill on vs boundary admission (``refill=False``).
Per-request time-to-first-token and inter-token latencies come from the
engine's own ``t_submit`` / ``t_first`` / ``tok_times`` stamps; step units
convert to ms via the measured warm mean step wall time. Records:
``serve_ttft_ms`` / ``serve_itl_p50_ms`` / ``serve_itl_p99_ms`` (refill
engine) and the gate ``serve_refill_ttft_speedup`` — mean boundary TTFT
over mean refill TTFT on the identical trace, which must be > 1.0:
recycling finished slots into the live chunk stream MUST beat waiting for
admission-batch boundaries.

Saturated admission (token packing): a second, much hotter Poisson trace
(arrivals ~0.2 steps apart — far faster than service, so many admission
batches are in flight at once) is replayed against a token-packed refill
engine (``ServeConfig.token_budget``), the bucketed chunked refill
engine it replaces, and BOUNDARY admission (refill off) — the strongest
chunked baseline in this regime, since boundary's full batches amortize
bucket padding better than refill's partial ones (the PR 7 caveat that
motivated packing). Records: ``serve_packed_saturated_tokens_per_s``
(informational wall-clock rate) and the gate
``serve_packed_saturated_speedup`` — BOUNDARY steps-to-drain over packed
steps-to-drain on the shared virtual clock (deterministic), which must
be >= 1.0: packing true prompt tokens across all in-flight batches must
beat even the best per-batch chunking admission policy (chunked-refill
steps ride along informationally).

Fleet (multi-replica fabric, :mod:`repro.serve.fleet`): the saturated
trace is replayed through in-process replica fleets on the same virtual
clock. ``serve_fleet_migration_completed`` kills one of 4 replicas
mid-trace and gates on every request completing with tokens identical to
the no-kill replay (fail-stop migration: queued requests replay, decoding
requests resume from their generated prefix — the caller never loses or
repeats a token). ``serve_fleet_scaleup_ttft_speedup`` replays the trace
on 2- and 4-replica fleets and gates mean TTFT (step units) improving
with the larger pool (> 1.0) — the router's least-loaded dispatch must
actually convert replicas into admission capacity. CI requires both
records.
"""
from __future__ import annotations

import json
import pathlib
import time

import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import (Fleet, FleetConfig, PerSlotEngine, Request,
                         ServeConfig, ServeEngine)


def _derive(emit, records, tps, *, prefix: str, label: str, main: str,
            base: str, ft: dict) -> bool:
    """Speedup gate (>= 2x) + per-scope ft-overhead records, shared by the
    decode and prefill waves. ``ft`` maps protection scope -> variant name
    (e.g. {"head": "serve_batched_ft", "all": "serve_batched_ft_all"}).
    A small/negative ft delta is run-to-run noise, not a real negative
    cost — clamp so the artifact never claims an impossible "upper
    bound"."""
    speedup = tps[main] / tps[base]
    ok = speedup >= 2.0
    emit(f"{prefix}_speedup", 0.0,
         f"{label} {speedup:.2f}x (gate >= 2x: "
         f"{'PASS' if ok else 'FAIL'})")
    records.append({"name": f"{prefix}_speedup", "value": round(speedup, 2),
                    "gate": ">= 2.0", "ok": ok})
    for scope, variant in ft.items():
        ft_overhead = (tps[main] / tps[variant] - 1) * 100
        below_noise = ft_overhead < 2.0
        ft_overhead = max(ft_overhead, 0.0)
        suffix = "" if scope == "head" else f"_{scope}"
        emit(f"{prefix}_ft_overhead{suffix}", 0.0,
             f"entangled[{scope}] +{ft_overhead:.1f}%"
             f"{' (below measurement noise)' if below_noise else ''} "
             f"(interpret CPU upper bound)")
        records.append({"name": f"{prefix}_ft_overhead_pct{suffix}",
                        "scope": scope,
                        "value": round(ft_overhead, 1),
                        "below_noise": below_noise,
                        "note": "interpret CPU upper bound; TPU target is "
                                "the paper's 1.8-2.8% band"})
    return ok


def _coverage(emit, records, name: str, eng, want: set) -> bool:
    """Record the protected-site categories a scope=all engine compiled
    plans for — the 'ft_scope=all means ALL' regression gate."""
    cats = {"head"} | (set(eng.plans.categories()) if eng.plans else set())
    ok = want <= cats
    emit(name, 0.0, f"categories={sorted(cats)} "
                    f"(gate >= {sorted(want)}: {'PASS' if ok else 'FAIL'})")
    records.append({"name": name, "categories": sorted(cats),
                    "required": sorted(want), "ok": ok})
    return ok


def _wave(eng, prompts, max_new: int) -> tuple[float, int, int]:
    """Run one request wave to completion; returns (seconds, tokens,
    decode_calls) for THIS wave only."""
    for r, p in enumerate(prompts):
        eng.submit(Request(rid=r, prompt=p.copy(), max_new=max_new))
    calls0 = eng.decode_calls
    t0 = time.perf_counter()
    done = eng.run_to_completion(max_steps=100_000)
    dt = time.perf_counter() - t0
    toks = sum(len(r.out) for r in done)
    eng.done = []
    return dt, toks, eng.decode_calls - calls0


def _openloop(cfg, params, *, refill: bool, arrivals, prompts,
              max_new: int, mpps: int = 1, token_budget: int = 0):
    """Replay one seeded open-loop arrival trace on a fresh engine.

    The engine runs on a virtual clock advancing 1.0 per step, so TTFT /
    ITL — and steps-to-drain — come out in STEP units, deterministic
    across machines (jit compile stalls inside a step cannot leak into
    latency). ``token_budget > 0`` runs token-packed admission. Two
    passes: the first compiles every program, the second (warm) is
    measured for the step -> wall-ms conversion. Returns (requests,
    ms_per_step, steps, engine) from the warm pass."""
    vclock = [0.0]
    eng = ServeEngine(
        cfg, ServeConfig(max_batch=8, max_seq=80, prefill_chunk=8,
                         prefill_buckets=(16, 64), refill=refill,
                         max_prefill_per_step=mpps,
                         token_budget=token_budget,
                         clock=lambda: vclock[0]), params)
    for _pass in range(2):
        vclock[0] = 0.0
        reqs, i, steps = [], 0, 0
        wall0 = time.perf_counter()
        while i < len(prompts) or not eng.idle():
            while i < len(prompts) and arrivals[i] <= vclock[0]:
                rq = Request(rid=i, prompt=prompts[i].copy(),
                             max_new=max_new)
                eng.submit(rq)
                reqs.append(rq)
                i += 1
            eng.step()
            steps += 1
            vclock[0] += 1.0
            assert steps < 10_000, "open-loop trace failed to drain"
        wall = time.perf_counter() - wall0
        eng.done = []
    return reqs, wall / steps * 1e3, steps, eng


def _fleet_trace(cfg, params, *, replicas: int, arrivals, prompts,
                 max_new: int, kill_at=None, kill_rid: int = 0):
    """Replay one open-loop arrival trace through an in-process replica
    fleet on the shared virtual clock (1 unit per fleet step). The
    per-replica ServeConfig matches ``_openloop``'s shapes, so every
    program is already compiled by the earlier sections — fleet replays
    measure scheduling, not jit. ``kill_at`` injects a whole-replica
    fail-stop at that step. Returns (requests, steps, fleet)."""
    vclock = [0.0]
    fleet = Fleet(
        cfg, ServeConfig(max_batch=8, max_seq=80, prefill_chunk=8,
                         prefill_buckets=(16, 64),
                         clock=lambda: vclock[0]), params,
        FleetConfig(replicas=replicas))
    reqs, i, steps = [], 0, 0
    while i < len(prompts) or not fleet.idle():
        while i < len(prompts) and arrivals[i] <= vclock[0]:
            rq = Request(rid=i, prompt=prompts[i].copy(), max_new=max_new)
            fleet.submit(rq)
            reqs.append(rq)
            i += 1
        if steps == kill_at:
            fleet.kill_replica(kill_rid)
        fleet.step()
        steps += 1
        vclock[0] += 1.0
        assert steps < 10_000, "fleet trace failed to drain"
    return reqs, steps, fleet


def run(emit, *, max_batch: int = 8, n_requests: int = 16,
        max_new: int = 16, ft_M: int = 4, repeats: int = 3,
        prompt_len: int = 12) -> bool:
    cfg = get_smoke_config("llama3.2-1b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg, max_seq=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(n_requests)]

    variants = {
        "serve_per_slot": PerSlotEngine(
            cfg, ServeConfig(max_batch=max_batch, max_seq=64), params),
        "serve_batched": ServeEngine(
            cfg, ServeConfig(max_batch=max_batch, max_seq=64), params),
        "serve_batched_ft": ServeEngine(
            cfg, ServeConfig(max_batch=max_batch, max_seq=64,
                             ft_mode="entangle", ft_M=ft_M), params),
        "serve_batched_ft_all": ServeEngine(
            cfg, ServeConfig(max_batch=max_batch, max_seq=64,
                             ft_mode="entangle", ft_M=ft_M,
                             ft_scope="all"), params),
        "serve_batched_ft_all_unpacked": ServeEngine(
            cfg, ServeConfig(max_batch=max_batch, max_seq=64,
                             ft_mode="entangle", ft_M=ft_M,
                             ft_scope="all", ft_packed=False,
                             ft_chain=False), params),
    }

    records = []
    tps = {}
    for name, eng in variants.items():
        _wave(eng, prompts, max_new)  # warm: compile every program
        best_dt, toks, calls = min(
            (_wave(eng, prompts, max_new) for _ in range(repeats)),
            key=lambda r: r[0])
        tps[name] = toks / best_dt
        emit(name, best_dt / max(toks, 1) * 1e6, f"{tps[name]:.1f} tok/s")
        records.append({"name": name, "tokens_per_s": round(tps[name], 1),
                        "seconds": round(best_dt, 4), "tokens": toks,
                        "decode_calls": calls})

    ok = _derive(emit, records, tps, prefix="serve",
                 label="batched/per-slot", main="serve_batched",
                 base="serve_per_slot",
                 ft={"head": "serve_batched_ft",
                     "all": "serve_batched_ft_all",
                     "all_packed": "serve_batched_ft_all",
                     "all_unpacked": "serve_batched_ft_all_unpacked"})

    # coverage gates: scope=all really protects every category. The dense
    # arch above covers head/qkv/mlp/out; the MoE categories (grouped
    # per-expert GEMMs + router) are asserted on a census-only MoE engine —
    # startup plan compilation is cheap (abstract traces, no kernels), so
    # no extra wave is needed.
    ok &= _coverage(emit, records, "serve_ft_coverage_all",
                    variants["serve_batched_ft_all"],
                    {"head", "qkv", "mlp", "out"})
    moe_cfg = get_smoke_config("deepseek-v2-lite-16b")
    moe_params = get_model(moe_cfg).init(jax.random.PRNGKey(0), moe_cfg,
                                         max_seq=64)
    moe_eng = ServeEngine(
        moe_cfg, ServeConfig(max_batch=max_batch, max_seq=64,
                             ft_mode="entangle", ft_M=ft_M,
                             ft_scope="all"), moe_params)
    ok &= _coverage(emit, records, "serve_ft_coverage_moe", moe_eng,
                    {"head", "qkv", "mlp", "out", "moe"})

    # -- prompt-heavy admission wave: pure prefill throughput ----------------
    # max_new=1 requests finish at admission, so the wave measures ONLY the
    # prefill pipeline: per-request batch-1 calls vs bucketed batched calls
    # (prompt length 12 -> bucket 16, n_requests/max_batch batched calls).
    pre_prompts = [rng.integers(0, cfg.vocab_size, prompt_len)
                   .astype(np.int32) for _ in range(n_requests)]
    ptoks = n_requests * prompt_len
    pre_variants = {
        "prefill_per_request": PerSlotEngine(
            cfg, ServeConfig(max_batch=max_batch, max_seq=64), params),
        "prefill_bucketed": ServeEngine(
            cfg, ServeConfig(max_batch=max_batch, max_seq=64), params),
        "prefill_bucketed_ft": ServeEngine(
            cfg, ServeConfig(max_batch=max_batch, max_seq=64,
                             ft_mode="entangle", ft_M=ft_M), params),
        "prefill_bucketed_ft_all": ServeEngine(
            cfg, ServeConfig(max_batch=max_batch, max_seq=64,
                             ft_mode="entangle", ft_M=ft_M,
                             ft_scope="all"), params),
    }
    ptps = {}
    for name, eng in pre_variants.items():
        _wave(eng, pre_prompts, 1)  # warm: compile every bucket program
        best_dt = min(_wave(eng, pre_prompts, 1)[0] for _ in range(repeats))
        ptps[name] = ptoks / best_dt
        emit(name, best_dt / ptoks * 1e6, f"{ptps[name]:.1f} prompt tok/s")
        records.append({"name": name,
                        "prompt_tokens_per_s": round(ptps[name], 1),
                        "seconds": round(best_dt, 4),
                        "prompt_tokens": ptoks})

    ok &= _derive(emit, records, ptps, prefix="prefill",
                  label="bucketed/per-request", main="prefill_bucketed",
                  base="prefill_per_request",
                  ft={"head": "prefill_bucketed_ft",
                      "all": "prefill_bucketed_ft_all"})

    # -- steady-state latency: open-loop trace, refill vs boundary -----------
    # Mixed trace: periodic LONG prompts (56 -> bucket 64, 8 chunks of 8)
    # keep an admission batch mid-flight for many steps while short
    # prompts (12 -> bucket 16) keep arriving; short max_new churns slots
    # free mid-chunk. Refill admits the shorts into those freed slots
    # immediately; boundary admission parks them until the long batch
    # drains — that wait is exactly the TTFT gap this gate measures.
    trace_rng = np.random.default_rng(7)
    lens = [56 if j % 6 == 0 else 12 for j in range(24)]
    trace_prompts = [trace_rng.integers(0, cfg.vocab_size, n)
                     .astype(np.int32) for n in lens]
    trace_arrivals = np.cumsum(trace_rng.exponential(1.5, size=len(lens)))
    lat = {}
    for mode, refill in (("refill", True), ("boundary", False)):
        reqs, ms_per_step, _, eng = _openloop(
            cfg, params, refill=refill, arrivals=trace_arrivals,
            prompts=trace_prompts, max_new=4, mpps=2)
        assert all(r.status == "done" for r in reqs)
        ttft = np.array([r.t_first - r.t_submit for r in reqs])
        itl = np.concatenate([np.diff(r.tok_times) for r in reqs
                              if len(r.tok_times) > 1])
        lat[mode] = {"ttft_steps": float(ttft.mean()),
                     "ms_per_step": ms_per_step,
                     "itl_steps": itl,
                     "refills": eng.metrics["refill_admissions"]}
    assert lat["refill"]["refills"] > 0, "trace never exercised refill"
    assert lat["boundary"]["refills"] == 0
    ms = lat["refill"]["ms_per_step"]
    ttft_ms = lat["refill"]["ttft_steps"] * ms
    itl_ms = lat["refill"]["itl_steps"] * ms
    p50, p99 = np.percentile(itl_ms, [50, 99])
    speedup = lat["boundary"]["ttft_steps"] / lat["refill"]["ttft_steps"]
    lat_ok = speedup > 1.0
    emit("serve_ttft_ms", ttft_ms * 1e3,
         f"open-loop mean TTFT {ttft_ms:.1f} ms (refill; "
         f"{lat['refill']['ttft_steps']:.2f} steps x {ms:.1f} ms/step)")
    itl_max_steps = float(lat["refill"]["itl_steps"].max())
    emit("serve_itl_p50_ms", p50 * 1e3, f"ITL p50 {p50:.1f} ms")
    emit("serve_itl_p99_ms", p99 * 1e3,
         f"ITL p99 {p99:.1f} ms (max {itl_max_steps:.0f} step(s)/token — "
         f"1 means decode was NEVER starved by admission chunks)")
    emit("serve_refill_ttft_speedup", 0.0,
         f"refill vs boundary TTFT {speedup:.2f}x "
         f"(gate > 1.0: {'PASS' if lat_ok else 'FAIL'})")
    records.append({"name": "serve_ttft_ms", "value": round(ttft_ms, 2),
                    "ttft_steps": round(lat["refill"]["ttft_steps"], 3),
                    "ms_per_step": round(ms, 3),
                    "refill_admissions": lat["refill"]["refills"]})
    records.append({"name": "serve_itl_p50_ms", "value": round(p50, 2)})
    records.append({"name": "serve_itl_p99_ms", "value": round(p99, 2),
                    "itl_max_steps": itl_max_steps,
                    "decode_starved": itl_max_steps > 1.0})
    records.append({"name": "serve_refill_ttft_speedup",
                    "value": round(speedup, 3),
                    "boundary_ttft_steps":
                        round(lat["boundary"]["ttft_steps"], 3),
                    "gate": "> 1.0", "ok": lat_ok})
    ok &= lat_ok

    # -- saturated open-loop: token-packed vs bucketed chunked admission -----
    # Arrivals far faster than service (0.2 steps apart, mixed 12/56
    # prompts) keep many admission batches in flight at once — the regime
    # token packing exists for. All engines advance ONE prefill program
    # per step; steps-to-drain on the shared virtual clock is the
    # deterministic figure of merit. The chunked engines' one program
    # advances one batch's chunk (refill batches formed under free-slot
    # pressure are often partial, and bucket padding burns whole chunks —
    # which is why BOUNDARY admission, full batches only, is the stronger
    # chunked baseline here and the one the gate compares against); the
    # packed engine's one program advances up to token_budget TRUE prompt
    # tokens drawn across ALL in-flight batches.
    sat_rng = np.random.default_rng(11)
    sat_lens = [56 if j % 4 == 0 else 12 for j in range(32)]
    sat_prompts = [sat_rng.integers(0, cfg.vocab_size, n).astype(np.int32)
                   for n in sat_lens]
    sat_arrivals = np.cumsum(sat_rng.exponential(0.2, size=len(sat_lens)))
    sat = {}
    for mode, tb, rf in (("packed", 64, True), ("chunked", 0, True),
                         ("boundary", 0, False)):
        reqs, ms_per_step, steps, eng = _openloop(
            cfg, params, refill=rf, arrivals=sat_arrivals,
            prompts=sat_prompts, max_new=4, mpps=1, token_budget=tb)
        assert all(r.status == "done" for r in reqs)
        toks = sum(len(r.prompt) + len(r.out) for r in reqs)
        sat[mode] = {"steps": steps, "ms_per_step": ms_per_step,
                     "tokens": toks,
                     "tokens_per_s": toks / (steps * ms_per_step / 1e3),
                     "metrics": dict(eng.metrics)}
    assert sat["packed"]["metrics"]["packed_calls"] > 0
    # _openloop replays the trace twice (cold + warm) on one engine, so
    # the packed-token counter sees every TRUE prompt token exactly twice
    assert sat["packed"]["metrics"]["packed_tokens"] == 2 * sum(sat_lens)
    sat_speedup = sat["boundary"]["steps"] / sat["packed"]["steps"]
    sat_ok = sat_speedup >= 1.0
    emit("serve_packed_saturated_tokens_per_s",
         1e6 / sat["packed"]["tokens_per_s"],
         f"saturated packed {sat['packed']['tokens_per_s']:.1f} tok/s "
         f"({sat['packed']['steps']} steps; co-packed batches peak "
         f"{sat['packed']['metrics']['packed_batches_peak']})")
    emit("serve_packed_saturated_speedup", 0.0,
         f"packed vs boundary steps-to-drain {sat_speedup:.2f}x "
         f"({sat['boundary']['steps']} -> {sat['packed']['steps']} steps; "
         f"chunked-refill {sat['chunked']['steps']}; "
         f"gate >= 1.0: {'PASS' if sat_ok else 'FAIL'})")
    records.append({
        "name": "serve_packed_saturated_tokens_per_s",
        "value": round(sat["packed"]["tokens_per_s"], 1),
        "steps": sat["packed"]["steps"],
        "tokens": sat["packed"]["tokens"],
        "packed_tokens": sat["packed"]["metrics"]["packed_tokens"],
        "packed_calls": sat["packed"]["metrics"]["packed_calls"],
        "packed_batches_peak":
            sat["packed"]["metrics"]["packed_batches_peak"]})
    records.append({
        "name": "serve_packed_saturated_speedup",
        "value": round(sat_speedup, 3),
        "boundary_steps": sat["boundary"]["steps"],
        "chunked_steps": sat["chunked"]["steps"],
        "packed_steps": sat["packed"]["steps"],
        "gate": ">= 1.0", "ok": sat_ok})
    ok &= sat_ok

    # -- fleet: fail-stop migration + replica scale-out ----------------------
    # The saturated trace again, now through the multi-replica fabric.
    # Migration gate: kill replica 1 of 4 mid-trace; every request must
    # still complete, with tokens identical to the no-kill replay (greedy
    # decode is deterministic and migration resumes from the streamed
    # prefix, so a surviving caller cannot tell the difference).
    base_reqs, base_steps, _ = _fleet_trace(
        cfg, params, replicas=4, arrivals=sat_arrivals,
        prompts=sat_prompts, max_new=4)
    kill_reqs, kill_steps, kfleet = _fleet_trace(
        cfg, params, replicas=4, arrivals=sat_arrivals,
        prompts=sat_prompts, max_new=4, kill_at=6, kill_rid=1)
    km = kfleet.fleet_metrics()
    completed = all(r.status == "done" for r in kill_reqs)
    identical = (len(kill_reqs) == len(base_reqs) and all(
        np.array_equal(a.out, b.out)
        for a, b in zip(kill_reqs, base_reqs)))
    mig_ok = (completed and identical and km["failed"] == 1
              and km["router_migrated"] >= 1)
    emit("serve_fleet_migration_completed", 0.0,
         f"killed 1/4 replicas at step 6: "
         f"{sum(r.status == 'done' for r in kill_reqs)}/{len(kill_reqs)} "
         f"completed, tokens {'identical' if identical else 'DIVERGED'} "
         f"vs no-kill replay; migrated={km['router_migrated']} "
         f"(prefix-resume={km['router_resume_prefix']}, "
         f"recompute={km['router_resume_recompute']}, "
         f"replayed={km['router_replayed']}); drain "
         f"{base_steps} -> {kill_steps} steps "
         f"({'PASS' if mig_ok else 'FAIL'})")
    records.append({
        "name": "serve_fleet_migration_completed",
        "completed": sum(r.status == "done" for r in kill_reqs),
        "requests": len(kill_reqs),
        "tokens_identical": identical,
        "migrated": km["router_migrated"],
        "resume_prefix": km["router_resume_prefix"],
        "resume_recompute": km["router_resume_recompute"],
        "replayed": km["router_replayed"],
        "nokill_steps": base_steps, "kill_steps": kill_steps,
        "gate": "all complete, tokens identical to no-kill replay",
        "ok": mig_ok})
    ok &= mig_ok

    # Scale-out gate: same saturated trace on a 2-replica fleet; the
    # 4-replica mean TTFT (step units, deterministic) must beat it — the
    # router's least-loaded dispatch has to turn replicas into admission
    # capacity, not just spares.
    small_reqs, small_steps, _ = _fleet_trace(
        cfg, params, replicas=2, arrivals=sat_arrivals,
        prompts=sat_prompts, max_new=4)
    ttft = {}
    for label, rs in (("2", small_reqs), ("4", base_reqs)):
        assert all(r.status == "done" for r in rs)
        ttft[label] = float(np.mean([r.t_first - r.t_submit for r in rs]))
    fleet_speedup = ttft["2"] / ttft["4"]
    scale_ok = fleet_speedup > 1.0
    emit("serve_fleet_scaleup_ttft_speedup", 0.0,
         f"saturated TTFT 2->4 replicas {fleet_speedup:.2f}x "
         f"({ttft['2']:.2f} -> {ttft['4']:.2f} steps; drain "
         f"{small_steps} -> {base_steps} steps; gate > 1.0: "
         f"{'PASS' if scale_ok else 'FAIL'})")
    records.append({
        "name": "serve_fleet_scaleup_ttft_speedup",
        "value": round(fleet_speedup, 3),
        "ttft_steps_2_replicas": round(ttft["2"], 3),
        "ttft_steps_4_replicas": round(ttft["4"], 3),
        "drain_steps_2_replicas": small_steps,
        "drain_steps_4_replicas": base_steps,
        "gate": "> 1.0", "ok": scale_ok})
    ok &= scale_ok

    path = pathlib.Path.cwd() / "BENCH_serve.json"
    path.write_text(json.dumps({
        "meta": {"backend": jax.default_backend(),
                 "max_batch": max_batch, "n_requests": n_requests,
                 "max_new": max_new, "prompt_len": prompt_len,
                 "ft_M": ft_M, "ok": ok},
        "records": records,
    }, indent=1))
    return ok
