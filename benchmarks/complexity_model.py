"""Paper Sec. IV: operation-count model.

  C_GEMM        = M N^3            C_ne_GEMM   = 2 M N^2
  C_conv_time   = 4 M N^2          C_ne_conv   = 2 M N
  C_conv_freq   = M[(45N+15)log2(3N+1)+3N+1]
  C_cs_*        = +1/M of the main op + 2MN(^2) checksum generation

Claims checked: NE relative overhead < 0.3% for practical N, M and -> 0 as
N -> inf; checksum overhead -> 1/M (> 4% even at M=32)."""
from __future__ import annotations

import math


def ne_gemm_ratio(M, N):
    return (2 * M * N**2) / (M * N**3)


def ne_conv_time_ratio(M, N):
    return (2 * M * N) / (4 * M * N**2)


def ne_conv_freq_ratio(M, N):
    c = M * ((45 * N + 15) * math.log2(3 * N + 1) + 3 * N + 1)
    return (2 * M * N) / c


def cs_gemm_ratio(M, N):
    return (2 * M * N**2 + (M * N**3) / M) / (M * N**3)


def cs_conv_time_ratio(M, N):
    return (2 * M * N + (4 * M * N**2) / M) / (4 * M * N**2)


def run(emit):
    for M in (3, 8, 32):
        for N in (100, 1000):
            r_g, r_ct, r_cf = (ne_gemm_ratio(M, N), ne_conv_time_ratio(M, N),
                               ne_conv_freq_ratio(M, N))
            worst = max(r_g, r_ct, r_cf) * 100
            emit(f"complexity_ne_M{M}_N{N}", 0.0,
                 f"gemm_pct={r_g*100:.4f};conv_time_pct={r_ct*100:.4f};"
                 f"conv_freq_pct={r_cf*100:.4f};below_0.3pct={worst < 0.3}")
            cs_g, cs_c = cs_gemm_ratio(M, N) * 100, cs_conv_time_ratio(M, N) * 100
            emit(f"complexity_cs_M{M}_N{N}", 0.0,
                 f"gemm_pct={cs_g:.2f};conv_time_pct={cs_c:.2f};"
                 f"ge_1_over_M={cs_g >= 100/M}")
    # Gated claims: NE time-domain overheads < 0.3% at N=1000; NE -> 0 and
    # checksum -> 1/M asymptotically. NOTE (recorded in EXPERIMENTS.md): the
    # paper's blanket "below 0.3% for 100<=N<=1000" does NOT follow from its
    # own formulas at N=100 (2/N = 2% for GEMM) — only the N~1000 end holds.
    big = 10**7
    ok = (ne_gemm_ratio(3, 1000) * 100 < 0.3
          and ne_conv_time_ratio(3, 1000) * 100 < 0.3
          and ne_gemm_ratio(8, big) < 1e-5
          and abs(cs_gemm_ratio(8, big) - 1 / 8) < 1e-4)
    emit("complexity_asymptotics", 0.0,
         f"ne_to_zero={ne_gemm_ratio(8, big):.2e};"
         f"cs_to_1overM={cs_gemm_ratio(8, big):.4f};claims_hold_at_N1000={ok};"
         f"paper_0.3pct_claim_fails_at_N100=gemm2.0pct")
    return ok
