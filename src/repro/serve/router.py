"""Front-end router of the multi-replica serving fabric: one request
queue above N replica engines, with fail-stop migration.

The router owns ADMISSION for the whole fleet — ``max_queue`` saturation
control, EDF ordering and deadline shedding move up here (the per-replica
:class:`~repro.serve.scheduler.ChunkScheduler` keeps ordering the prefill
chunks *inside* each engine) — and it owns the only state recovery ever
needs: a per-request census of what was dispatched where and which tokens
have streamed back.

Request flow
------------
``submit()`` registers a :class:`FleetRecord` and returns the standard
:class:`~repro.serve.scheduler.RequestHandle` over a ROUTER-level
:class:`~repro.serve.scheduler.TokenRing`. Dispatch picks the least-loaded
HEALTHY replica and submits a SHADOW request to its engine; after each
fleet step the router drains the shadow's engine-level ring into the
router-level ring. The caller's handle therefore never references a
replica: iterating it keeps yielding tokens across a replica fail-stop —
the iterator cannot even observe that a migration happened.

Fail-stop migration
-------------------
When a replica dies, its engine state (KV cache, slots, in-flight
admission batches) is unrecoverable. The router re-dispatches every
affected request from its own census:

  * **queued / mid-prefill** rows (no tokens streamed yet) simply replay:
    the prompt re-enters the router queue and prefills — batched, through
    the normal admission pipeline — on a healthy replica.
  * **decoding** rows resume from their generated-token PREFIX: the
    shadow prompt becomes ``prompt + tokens_so_far`` and ``max_new``
    shrinks by the prefix length, so recovery costs one batched prefill
    of the context — independent of how many decode steps the dead
    replica had already spent (the fault-oblivious no-rollback property).
    Greedy decode is deterministic and the engine's prefill/decode paths
    are bit-identical, so the continuation tokens equal the no-failure
    run's exactly (tested).
  * when the prefix outgrows the largest prefill bucket, the router falls
    back to **recompute**: the original prompt replays with full
    ``max_new`` and the first ``len(prefix)`` regenerated tokens are
    suppressed at drain time — the caller's stream never repeats a token.

Migrated requests keep their original ``t_submit``, so EDF puts them at
the front of their deadline class; they are never deadline-shed (their
admission already happened — the compute is sunk, and shedding them would
turn a replica failure into a visible SLA failure).
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import numpy as np

from repro.serve.engine import Request, ServeConfig, resolve_buckets
from repro.serve.scheduler import (ChunkScheduler, RequestHandle, TokenRing)
from repro.serve.transport import ReplicaDead


@dataclasses.dataclass
class FleetRecord:
    """The router's census entry for one submitted request — everything
    migration needs, and nothing a dead replica holds: the caller's
    request, the router-level ring its handle pops, every token emitted
    so far (the migration prefix), and the current shadow dispatch."""

    req: Request
    ring: TokenRing
    toks: list = dataclasses.field(default_factory=list)
    replica: Optional[int] = None  # replica id; None = in the router queue
    shadow: Optional[Request] = None  # engine-level request on the replica
    eh: Optional[RequestHandle] = None  # engine handle (token source)
    skip: int = 0  # regenerated-prefix tokens to suppress (recompute path)
    migrations: int = 0
    dispatched: bool = False  # ever admitted to a replica (never shed then)


class Router:
    """Fleet front-end: request queue, dispatch, token drain, migration.

    The fleet calls the phases in order each step: :meth:`shed` ->
    :meth:`dispatch` -> (replica steps) -> :meth:`drain`; :meth:`migrate`
    fires whenever a replica is declared dead. ``fleet`` only needs
    ``step()`` / ``cancel()`` (the :class:`RequestHandle` contract) and a
    way to look up transports by replica id (``transport_of``)."""

    def __init__(self, fleet, scfg: ServeConfig):
        self.fleet = fleet
        self.scfg = scfg
        self.clock = scfg.clock or time.monotonic
        # router-level admission control: the engine-side queues stay
        # unbounded — the router is the fleet's single gatekeeper
        self.sched = ChunkScheduler(max_queue=scfg.max_queue,
                                    clock=self.clock)
        self.buckets = resolve_buckets(scfg)
        self.queue: List[Request] = []
        self.records: dict[int, FleetRecord] = {}  # id(req) -> record
        self.metrics = {"queue_depth_peak": 0, "rejected": 0, "shed": 0,
                        "cancelled": 0, "migrated": 0, "resume_prefix": 0,
                        "resume_recompute": 0, "replayed": 0}

    # -- admission ------------------------------------------------------------

    def submit(self, req: Request) -> RequestHandle:
        """Register a request with the fleet. Capacity contracts are the
        engine's, enforced HERE (the request may land on any replica —
        including one spawned later — so the bounds must hold fleet-wide);
        saturation raises the same typed
        :class:`~repro.serve.scheduler.AdmissionRejected`."""
        if len(req.prompt) > self.buckets[-1]:
            raise ValueError(
                f"request rid={req.rid} prompt length {len(req.prompt)} > "
                f"largest prefill bucket {self.buckets[-1]} (configure "
                f"prefill_buckets / raise max_seq)")
        need = len(req.prompt) + req.max_new
        if need > self.scfg.max_seq:
            raise ValueError(
                f"request rid={req.rid} needs {need} positions "
                f"(prompt {len(req.prompt)} + max_new {req.max_new}) "
                f"> max_seq={self.scfg.max_seq}")
        try:
            self.sched.check_admission(req.rid, len(self.queue))
        except Exception:
            self.metrics["rejected"] += 1
            raise
        req.status = "queued"
        req.t_submit = self.clock()
        rec = FleetRecord(req=req, ring=TokenRing(req.max_new))
        self.records[id(req)] = rec
        self.queue.append(req)
        self.metrics["queue_depth_peak"] = max(
            self.metrics["queue_depth_peak"], len(self.queue))
        return RequestHandle(self.fleet, req, rec.ring)

    def shed(self):
        """Deadline-shed lapsed QUEUED requests that were never admitted
        anywhere. Migrated requests are exempt: their admission happened —
        a replica failure must not become a visible SLA failure."""
        if not any(r.deadline_ms is not None and
                   not self.records[id(r)].dispatched for r in self.queue):
            return
        fresh = [r for r in self.queue
                 if not self.records[id(r)].dispatched]
        kept, shed = self.sched.shed_expired(fresh)
        if not shed:
            return
        gone = {id(r) for r in shed}
        self.queue = [r for r in self.queue if id(r) not in gone]
        now = self.clock()
        for req in shed:
            req.status = "shed"
            req.out = np.zeros(0, np.int32)
            req.t_done = now
            del self.records[id(req)]
            self.metrics["shed"] += 1

    # -- dispatch -------------------------------------------------------------

    def load(self, replica_id: int) -> int:
        """Live records assigned to a replica — the dispatch balance key
        and the per-replica backpressure bound (capacity = max_batch: the
        router never queues more work on a replica than its slot pool,
        keeping the migration blast radius and the router queue — the
        scaling signal — both honest)."""
        return sum(1 for rec in self.records.values()
                   if rec.replica == replica_id)

    def dispatch(self, healthy: list):
        """Assign queued requests (EDF order) to the least-loaded healthy
        replicas, up to each replica's slot capacity. ``healthy`` is a
        list of objects with ``rid`` + ``transport`` (fleet Replicas)."""
        if not self.queue or not healthy:
            return
        loads = {rep.rid: self.load(rep.rid) for rep in healthy}
        by_rid = {rep.rid: rep for rep in healthy}
        remaining = []
        for req in self.sched.order_queue(self.queue):
            rid = min((r for r in loads if loads[r] < self.scfg.max_batch),
                      key=lambda r: (loads[r], r), default=None)
            if rid is None:
                remaining.append(req)
                continue
            if self._dispatch_one(self.records[id(req)], by_rid[rid]):
                loads[rid] += 1
            else:
                remaining.append(req)
        self.queue = remaining

    def _dispatch_one(self, rec: FleetRecord, rep) -> bool:
        """Submit one record's shadow request to a replica. Returns False
        (leaving the record queued) if the replica died under us."""
        req = rec.req
        k = len(rec.toks)
        if k == 0:
            prompt, max_new, skip = req.prompt, req.max_new, 0
            if rec.migrations:
                self.metrics["replayed"] += 1
        elif len(req.prompt) + k <= self.buckets[-1]:
            # decode-prefix resume: prefill the generated prefix as
            # context, continue decoding where the dead replica stopped.
            # Cost: one batched prefill of len(prompt)+k tokens —
            # independent of the decode steps already performed.
            prompt = np.concatenate(
                [req.prompt, np.asarray(rec.toks, np.int32)])
            max_new, skip = req.max_new - k, 0
            self.metrics["resume_prefix"] += 1
        else:
            # prefix outgrew the bucket set: recompute from the original
            # prompt and suppress the k regenerated tokens at drain time
            # (greedy decode is deterministic, so they are the SAME k
            # tokens the caller already streamed)
            prompt, max_new, skip = req.prompt, req.max_new, k
            self.metrics["resume_recompute"] += 1
        shadow = Request(rid=req.rid, prompt=np.asarray(prompt, np.int32),
                         max_new=max_new, eos_token=req.eos_token)
        try:
            rec.eh = rep.transport.submit(shadow)
        except ReplicaDead:
            return False
        rec.shadow, rec.replica = shadow, rep.rid
        rec.skip, rec.dispatched = skip, True
        req.status = "prefill"
        return True

    # -- token drain ----------------------------------------------------------

    def drain(self):
        """Pull every shadow's newly generated tokens into the router-
        level rings, mirror engine status onto the caller's request, and
        finalize completed requests."""
        now = self.clock()
        for rec in list(self.records.values()):
            if rec.eh is None:
                continue
            req = rec.req
            while len(rec.eh.ring):
                tok = rec.eh.ring.pop()
                if rec.skip:
                    rec.skip -= 1
                    continue
                rec.toks.append(tok)
                rec.ring.push(tok)
                if req.t_first is None:
                    req.t_first = now
                req.tok_times.append(now)
            st = rec.shadow.status
            if st == "done":
                self._finalize(rec, now)
            elif st == "decoding":
                req.status = "decoding"
            # engine-queued / prefill shadows stay caller-visible as
            # "prefill": the request IS admitted fleet-side

    def _finalize(self, rec: FleetRecord, now: float):
        req = rec.req
        req.out = np.asarray(rec.toks[: req.max_new], np.int32)
        req.status = "done"
        req.t_done = now
        del self.records[id(req)]

    # -- migration ------------------------------------------------------------

    def migrate(self, replica_id: int):
        """Re-dispatch every request assigned to a dead replica from the
        router's census. Tokens already streamed are kept; the resume
        strategy (prefix vs recompute) is chosen per request at the next
        dispatch. The caller's handle keeps its ring — nothing observable
        changes except a short queue re-entry."""
        for rec in list(self.records.values()):
            if rec.replica != replica_id:
                continue
            rec.replica = rec.shadow = rec.eh = None
            rec.skip = 0
            rec.migrations += 1
            req = rec.req
            if len(rec.toks) >= req.max_new or (
                    req.eos_token is not None and rec.toks
                    and rec.toks[-1] == req.eos_token):
                # fully generated but not yet finalized (death raced the
                # drain): complete it — nothing left to recover
                self._finalize(rec, self.clock())
            else:
                req.status = "queued"
                self.queue.append(req)
            self.metrics["migrated"] += 1

    # -- cancellation / lifecycle --------------------------------------------

    def cancel(self, req: Request):
        """Fleet-wide cancel in any state: router-queued requests leave
        the queue; dispatched shadows cancel on their replica (a dead
        replica is moot — the state is gone anyway)."""
        rec = self.records.get(id(req))
        if rec is None or req.status in ("done", "cancelled", "shed"):
            return
        if rec.replica is None:
            self.queue = [r for r in self.queue if r is not req]
        else:
            tr = self.fleet.transport_of(rec.replica)
            if tr is not None:
                try:
                    tr.cancel(rec.shadow)
                except ReplicaDead:
                    pass
        req.status = "cancelled"
        req.out = np.asarray(rec.toks, np.int32)
        req.t_done = self.clock()
        del self.records[id(req)]
        self.metrics["cancelled"] += 1

    def assigned(self, replica_id: int) -> int:
        """Live records currently on a replica (drain-progress probe)."""
        return self.load(replica_id)

    def idle(self) -> bool:
        return not self.queue and not self.records
