"""Entangled int8 logits projection: the paper's technique on the serving
hot path.

The head GEMM (hidden [B, D] x head [D, V]) is sesquilinear, so it runs
directly on entangled inputs: the batch is split into M request groups
(streams), activations are fixed-point-quantized within the plan's eq. (13)
budget (a K-deep integer dot needs K * |a|max * |w|max <= D_max), and run
through the fused Pallas kernel — entangle-on-load, int GEMM, extraction in
the flush epilogue, one pallas_call, no codec HBM sweeps. Any single
group's fail-stop is rolled forward from the other M-1 entangled
accumulators inside the same kernel (``fuse_epilogue=False`` keeps the
separate disentangle pass for callers that must inject/persist entangled
outputs).

Returns dequantized float logits. Integer recovery is EXACT (tests assert
bit-equality under injected failure); the quantization itself trades logits
precision for protection like any int8 serving path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.entangle import disentangle
from repro.core.failstop import GARBAGE
from repro.core.plan import EntanglePlan, make_plan
from repro.kernels import ops as kops


def quantize_head(head: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 weight quantization."""
    amax = jnp.maximum(jnp.max(jnp.abs(head)), 1e-9)
    scale = 127.0 / amax
    return jnp.clip(jnp.round(head * scale), -127, 127).astype(jnp.int32), scale


def ft_logits(
    h: jax.Array,  # [B, D] float hidden states (final norm applied)
    head_q: jax.Array,  # [D, V] int8-range int32 weights
    w_scale: jax.Array,
    *,
    M: int = 4,
    plan: Optional[EntanglePlan] = None,
    failed_group: Optional[int] = None,
    use_pallas: bool = True,
    fuse_epilogue: bool = True,
    blocks=None,
) -> jax.Array:
    B, D = h.shape
    V = head_q.shape[1]
    assert B % M == 0, f"batch {B} must split into M={M} request groups"
    plan = plan or make_plan(M, 32)

    # activation budget so the K-deep int dot stays within eq. (13)
    a_budget = plan.max_output_magnitude // (D * 127)
    a_budget = max(a_budget, 1)
    amax = jnp.maximum(jnp.max(jnp.abs(h)), 1e-9)
    a_scale = a_budget / amax
    hq = jnp.round(h * a_scale).astype(jnp.int32).reshape(M, B // M, D)

    if use_pallas and fuse_epilogue:
        # production hot path: entangle -> GEMM -> extract in ONE
        # pallas_call; a fail-stopped group is rolled forward in-kernel by
        # statically excluding its accumulator from the extraction (the
        # algebra never reads it, so injecting garbage is equivalent)
        rec = kops.entangled_matmul(
            hq, head_q, plan, fuse_epilogue=True, failed=failed_group,
            blocks=blocks)
    else:
        if use_pallas:
            delta = kops.entangled_matmul(hq, head_q, plan, blocks=blocks)
        else:
            from repro.core.entangle import entangle

            eps = entangle(hq, plan)
            delta = jnp.einsum("mbk,kv->mbv", eps, head_q).astype(jnp.int32)

        if failed_group is not None:
            delta = delta.at[failed_group].set(GARBAGE)
        rec = disentangle(delta, plan, failed=failed_group)  # [M, B/M, V]
    logits = rec.astype(jnp.float32) / (a_scale * w_scale)
    return logits.reshape(B, V)
