"""DEPRECATED shim — the entangled logits projection lives in
:mod:`repro.ft.heads` since the entangled-ops v2 redesign.

Importing this module works but emits a :class:`DeprecationWarning`; every
public name (``quantize_head``, ``ft_logits``, ``ft_logits_decode``,
``ft_logits_prefill``, ``decode_group_order``) keeps its exact signature
and semantics, re-exported from the subsystem. Migrate imports::

    from repro.serve.ft_logits import ft_logits_decode   # old
    from repro.ft.heads import ft_logits_decode          # new

The shim (and a test locking its public surface,
``tests/test_ft_logits_shim.py``) stays until a release after every known
caller has migrated.
"""
from __future__ import annotations

import warnings

warnings.warn(
    "repro.serve.ft_logits is deprecated: the entangled head projection "
    "moved into the protected-GEMM subsystem — import quantize_head / "
    "ft_logits / ft_logits_decode / ft_logits_prefill from repro.ft.heads "
    "instead",
    DeprecationWarning,
    stacklevel=2,
)

from repro.ft.heads import (  # noqa: E402,F401  (re-exported surface)
    decode_group_order,
    ft_logits,
    ft_logits_decode,
    ft_logits_prefill,
    quantize_head,
)

__all__ = [
    "decode_group_order",
    "ft_logits",
    "ft_logits_decode",
    "ft_logits_prefill",
    "quantize_head",
]
