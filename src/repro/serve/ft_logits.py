"""Entangled int8 logits projection: the paper's technique on the serving
hot path.

The head GEMM (hidden [B, D] x head [D, V]) is sesquilinear, so it runs
directly on entangled inputs: the batch is split into M request groups
(streams), activations are fixed-point-quantized within the plan's eq. (13)
budget (a K-deep integer dot needs K * |a|max * |w|max <= D_max), and run
through the fused Pallas kernel — entangle-on-load, int GEMM, extraction in
the flush epilogue, one pallas_call, no codec HBM sweeps. Any single
group's fail-stop is rolled forward from the other M-1 entangled
accumulators inside the same kernel (``fuse_epilogue=False`` keeps the
separate disentangle pass for callers that must inject/persist entangled
outputs).

:func:`ft_logits` is the library form (caller-chosen contiguous grouping).
:func:`ft_logits_decode` is the batched serving engine's per-step entry:
slots map round-robin to groups (slot -> group = slot % M) so every group
stays populated under continuous batching, and the
:class:`~repro.core.plan.EntanglePlan` is made once at engine startup and
reused every step. :func:`ft_logits_prefill` is the admission-time entry —
the first token of every bucketed batched prefill goes through the same
fused kernel (and the same startup plan), so a fail-stop during prefill
rolls forward exactly like one during decode.

Returns dequantized float logits. Integer recovery is EXACT (tests assert
bit-equality under injected failure); the quantization itself trades logits
precision for protection like any int8 serving path.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.entangle import disentangle
from repro.core.failstop import GARBAGE
from repro.core.plan import EntanglePlan, make_plan
from repro.kernels import ops as kops


def quantize_head(head: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 weight quantization."""
    amax = jnp.maximum(jnp.max(jnp.abs(head)), 1e-9)
    scale = 127.0 / amax
    return jnp.clip(jnp.round(head * scale), -127, 127).astype(jnp.int32), scale


def ft_logits(
    h: jax.Array,  # [B, D] float hidden states (final norm applied)
    head_q: jax.Array,  # [D, V] int8-range int32 weights
    w_scale: jax.Array,
    *,
    M: int = 4,
    plan: Optional[EntanglePlan] = None,
    failed_group: Optional[int] = None,
    use_pallas: bool = True,
    fuse_epilogue: bool = True,
    blocks=None,
) -> jax.Array:
    B, D = h.shape
    V = head_q.shape[1]
    assert B % M == 0, f"batch {B} must split into M={M} request groups"
    plan = plan or make_plan(M, 32)

    # activation budget so the K-deep int dot stays within eq. (13)
    a_budget = plan.max_output_magnitude // (D * 127)
    a_budget = max(a_budget, 1)
    amax = jnp.maximum(jnp.max(jnp.abs(h)), 1e-9)
    a_scale = a_budget / amax
    hq = jnp.round(h * a_scale).astype(jnp.int32).reshape(M, B // M, D)

    if use_pallas and fuse_epilogue:
        # production hot path: entangle -> GEMM -> extract in ONE
        # pallas_call; a fail-stopped group is rolled forward in-kernel by
        # statically excluding its accumulator from the extraction (the
        # algebra never reads it, so injecting garbage is equivalent)
        rec = kops.entangled_matmul(
            hq, head_q, plan, fuse_epilogue=True, failed=failed_group,
            blocks=blocks)
    else:
        if use_pallas:
            delta = kops.entangled_matmul(hq, head_q, plan, blocks=blocks)
        else:
            from repro.core.entangle import entangle

            eps = entangle(hq, plan)
            delta = jnp.einsum("mbk,kv->mbv", eps, head_q).astype(jnp.int32)

        if failed_group is not None:
            delta = delta.at[failed_group].set(GARBAGE)
        rec = disentangle(delta, plan, failed=failed_group)  # [M, B/M, V]
    logits = rec.astype(jnp.float32) / (a_scale * w_scale)
    return logits.reshape(B, V)


# -- batched-decode entry -----------------------------------------------------

def decode_group_order(B: int, M: int) -> tuple[np.ndarray, np.ndarray]:
    """Static permutation realizing the engine's slot -> group = slot % M
    mapping on top of :func:`ft_logits`'s contiguous [M, B/M] grouping.

    ``order[g * B//M + j] = j * M + g`` — position p of the permuted batch
    holds slot ``order[p]``; ``inv`` undoes it (``inv[slot]`` = position of
    that slot's logits in the permuted output). Round-robin grouping keeps
    every entangled group populated whenever >= M slots are active, so a
    fail-stop in any group is recoverable from M-1 *other* live groups.
    """
    assert B % M == 0, f"batch {B} must split into M={M} request groups"
    order = np.arange(B, dtype=np.int32).reshape(B // M, M).T.reshape(B)
    inv = np.argsort(order).astype(np.int32)
    return order, inv


def ft_logits_decode(
    h: jax.Array,  # [B, D] hidden states of ONE engine decode step
    head_q: jax.Array,  # [D, V] int8-range int32 weights
    w_scale: jax.Array,
    *,
    plan: EntanglePlan,
    failed_group: Optional[int] = None,
    use_pallas: bool = True,
    fuse_epilogue: bool = True,
    blocks=None,
) -> jax.Array:
    """The serving engine's per-step entry: one fused entangled head GEMM
    over the whole slot batch, slots mapped round-robin to groups
    (slot -> group = slot % plan.M).

    Unlike :func:`ft_logits` the plan is REQUIRED: the engine makes it once
    at startup and reuses it every step, so no per-step (l, k) re-planning
    and a stable autotune/compile key across the serving lifetime.
    """
    B = h.shape[0]
    order, inv = decode_group_order(B, plan.M)
    logits = ft_logits(
        h[order], head_q, w_scale, M=plan.M, plan=plan,
        failed_group=failed_group, use_pallas=use_pallas,
        fuse_epilogue=fuse_epilogue, blocks=blocks)
    return logits[inv]


def ft_logits_prefill(
    h: jax.Array,  # [n, D] per-request last-prompt hidden states
    head_q: jax.Array,  # [D, V] int8-range int32 weights
    w_scale: jax.Array,
    *,
    plan: EntanglePlan,
    failed_group: Optional[int] = None,
    use_pallas: bool = True,
    fuse_epilogue: bool = True,
    blocks=None,
) -> jax.Array:
    """Admission-time entry: project the last-prompt hidden states gathered
    from a bucketed batched prefill through the SAME fused entangled kernel
    (and the same startup :class:`~repro.core.plan.EntanglePlan`) as decode,
    so a fail-stop injected while a prompt batch is being admitted rolls
    forward in-kernel and the first generated token is unchanged.

    Rows map round-robin to groups like decode (row -> group = row % M).
    An admission batch need not divide into M groups — the batch is padded
    with zero rows (exact: zeros entangle to zeros and cannot perturb any
    other stream's accumulator, nor the shared activation scale) and the
    pad logits are sliced off. The caller must zero any garbage rows (empty
    admission slots) before calling, exactly like the decode path's
    ``active`` masking, so they cannot poison the shared quantization scale.
    """
    n = h.shape[0]
    pad = (-n) % plan.M
    if pad:
        h = jnp.concatenate(
            [h, jnp.zeros((pad, h.shape[1]), h.dtype)], axis=0)
    logits = ft_logits_decode(
        h, head_q, w_scale, plan=plan, failed_group=failed_group,
        use_pallas=use_pallas, fuse_epilogue=fuse_epilogue, blocks=blocks)
    return logits[:n]
