"""Steady-state scheduling layer of the serving engine: deadline-aware
chunk scheduling, loud admission control, and the async per-request
frontend (token iterator / cancel / deadline).

The engine itself stays a synchronous step machine — one jitted decode per
:meth:`~repro.serve.ServeEngine.step`, static shapes everywhere. This
module adds the POLICY around it:

  * :class:`ChunkScheduler` — picks WHICH queued requests form the next
    admission batch and WHICH in-flight admission batch advances its next
    prefill chunk, earliest-deadline-first (EDF; deadline-less requests
    rank last, FIFO among themselves). Decode is never starved: at most
    ``max_prefill_per_step`` chunks run per engine step before the decode
    call, whatever the queue depth.
  * admission control — ``max_queue > 0`` bounds the wait queue; past it
    :meth:`ChunkScheduler.check_admission` raises :class:`AdmissionRejected`
    (a TYPED rejection, never a silent drop), and the engine's
    ``metrics["rejected"]`` / ``metrics["queue_depth_peak"]`` expose the
    shed load. Queued requests whose deadline expires before admission are
    shed loudly too (:meth:`shed_expired`; iterating their handle raises
    :class:`DeadlineExceeded`).
  * :class:`RequestHandle` — what ``submit()`` returns: a per-request
    token ITERATOR draining a fixed-capacity ring buffer
    (:class:`TokenRing`) the engine pushes into as each decode step lands.
    Iterating drives ``engine.step()`` on demand when the ring is empty, so
    a plain ``for tok in handle:`` loop streams tokens while the engine
    keeps serving every other slot; ``cancel()`` works in all three request
    states (queued / mid-prefill / decoding).

Nothing here touches jitted code: scheduling decisions only reorder host
lists and flip mask values, so program shapes — and therefore the FT
plans and the entangled roll-forward — are identical under every policy.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, List, Optional


class AdmissionRejected(RuntimeError):
    """Typed rejection raised by ``submit()`` at saturation (wait queue at
    ``max_queue``). Carries the observed queue depth so callers can
    backpressure instead of retry-storming."""

    def __init__(self, rid, depth: int, max_queue: int):
        self.rid, self.depth, self.max_queue = rid, depth, max_queue
        super().__init__(
            f"request rid={rid} rejected: wait queue at max_queue="
            f"{max_queue} (depth {depth})")


class DeadlineExceeded(RuntimeError):
    """Raised when iterating a handle whose request was shed because its
    ``deadline_ms`` expired before service completed admission."""

    def __init__(self, rid, deadline_ms: float):
        self.rid, self.deadline_ms = rid, deadline_ms
        super().__init__(
            f"request rid={rid} shed: deadline_ms={deadline_ms} expired "
            f"before admission")


class TokenRing:
    """Fixed-capacity int token ring buffer — the per-request streaming
    channel between the engine's decode loop (producer) and the request
    handle's iterator (consumer). Capacity is ``max_new`` so the producer
    can never overrun: the engine emits at most one token per request per
    step and stops at ``max_new``."""

    __slots__ = ("_buf", "_head", "_size")

    def __init__(self, capacity: int):
        self._buf: List[int] = [0] * max(int(capacity), 1)
        self._head = 0  # next pop index
        self._size = 0

    def __len__(self) -> int:
        return self._size

    def push(self, tok: int):
        if self._size >= len(self._buf):
            raise OverflowError("token ring full — engine emitted past "
                                "max_new, which step() must prevent")
        self._buf[(self._head + self._size) % len(self._buf)] = int(tok)
        self._size += 1

    def pop(self) -> int:
        if not self._size:
            raise IndexError("pop from empty token ring")
        tok = self._buf[self._head]
        self._head = (self._head + 1) % len(self._buf)
        self._size -= 1
        return tok


@dataclasses.dataclass
class ChunkScheduler:
    """Earliest-deadline-first chunk scheduling + loud admission control.

    Pure host-side policy over the engine's queues: no jax, no shapes.
    ``clock`` is injectable (tests pass a fake monotonic clock) and
    defaults to :func:`time.monotonic`.
    """

    max_prefill_per_step: int = 1  # chunk budget before each decode call
    max_queue: int = 0  # wait-queue bound; 0 = unbounded
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        if self.max_prefill_per_step < 1:
            raise ValueError(
                f"max_prefill_per_step must be >= 1, got "
                f"{self.max_prefill_per_step}")
        if self.max_queue < 0:
            raise ValueError(f"max_queue must be >= 0, got {self.max_queue}")

    def check_admission(self, rid, queue_depth: int):
        """Raise :class:`AdmissionRejected` when the wait queue is full."""
        if self.max_queue and queue_depth >= self.max_queue:
            raise AdmissionRejected(rid, queue_depth, self.max_queue)

    @staticmethod
    def _key(req, j: int):
        """EDF sort key: absolute deadline (submit time + deadline_ms),
        +inf for deadline-less requests; position breaks ties FIFO."""
        dl = getattr(req, "deadline_ms", None)
        if dl is None:
            return (float("inf"), j)
        return (req.t_submit + dl / 1e3, j)

    def order_queue(self, queue: list) -> list:
        """Queued requests in EDF order (stable: FIFO among equal/absent
        deadlines). Returns a NEW list; the caller owns the queue."""
        return [req for _, req in
                sorted(((self._key(r, j), r) for j, r in enumerate(queue)),
                       key=lambda kr: kr[0])]

    def pick_batch(self, batches: list) -> Optional[dict]:
        """Which in-flight admission batch advances its next chunk:
        earliest deadline first; among equal (or absent) deadlines,
        SHORTEST REMAINING PREFILL first, then FIFO. The SRJF tie-break is
        what turns mid-flight refill into a TTFT win: a short batch
        admitted into freed slots lands — i.e. emits its first tokens —
        after a couple of chunks while a long batch keeps streaming,
        instead of queuing behind the long batch's whole chunk tail."""
        if not batches:
            return None
        def batch_key(jp):
            j, p = jp
            reqs = [r for _, r in p["reqs"] if r is not None]
            if not reqs:
                return (float("-inf"), 0, j)  # all-cancelled: drain first
            dl = min(self._key(r, j)[0] for r in reqs)
            return (dl, p["bucket"] - p["pos0"], j)
        return min(enumerate(batches), key=batch_key)[1]

    def pack_rows(self, batches: list, budget_rows: int) -> list:
        """Token-packed prefill row selection: up to ``budget_rows``
        ``(batch, row_index)`` pairs forming the next packed program, drawn
        from ALL in-flight admission batches in the same EDF + shortest-
        remaining-prefill order as :meth:`pick_batch` — the packed step is
        the chunk budget, so the ordering policy is identical, just
        token-granular. Rows advance to their TRUE prompt length (bucket
        padding is never packed — the density win), each live slot appears
        at most once per call (the gather/scatter distinctness invariant),
        and cancelled rows are skipped entirely."""
        def batch_key(jp):
            j, p = jp
            reqs = [r for _, r in p["reqs"] if r is not None]
            if not reqs:
                return (float("-inf"), 0, j)
            dl = min(self._key(r, j)[0] for r in reqs)
            remaining = max(
                (int(p["lengths_np"][i]) - int(p["rowpos"][i])
                 for i, (_, r) in enumerate(p["reqs"]) if r is not None),
                default=0)
            return (dl, remaining, j)
        rows = []
        for _, p in sorted(enumerate(batches), key=batch_key):
            for i, (_, r) in enumerate(p["reqs"]):
                if r is None:
                    continue
                if int(p["rowpos"][i]) >= int(p["lengths_np"][i]):
                    continue  # row's prefill already complete
                rows.append((p, i))
                if len(rows) >= budget_rows:
                    return rows
        return rows

    def shed_expired(self, queue: list, now: Optional[float] = None) -> tuple:
        """Split the wait queue into (kept, shed): queued requests whose
        absolute deadline has passed are shed — they would miss their SLA
        anyway, and shedding them BEFORE prefill refunds the chunk budget
        to requests that can still make it. Requests already admitted
        (mid-prefill or decoding) are never shed: their compute is sunk and
        their slots free up in bounded time."""
        now = self.clock() if now is None else now
        kept, shed = [], []
        for req in queue:
            dl = getattr(req, "deadline_ms", None)
            if dl is not None and now > req.t_submit + dl / 1e3:
                shed.append(req)
            else:
                kept.append(req)
        return kept, shed


class RequestHandle:
    """Async frontend of one submitted request: iterate to stream tokens,
    ``cancel()`` to abandon it, ``result()`` to drain to completion.

    The iterator pops the per-request :class:`TokenRing`; when the ring is
    empty and the request unfinished, it drives ``engine.step()`` — each
    step advances EVERY active slot, so interleaved consumption of many
    handles costs the same total steps as ``run_to_completion``.
    """

    __slots__ = ("engine", "req", "ring", "_emitted")

    def __init__(self, engine, req, ring: TokenRing):
        self.engine, self.req, self.ring = engine, req, ring
        self._emitted = 0

    # -- state ----------------------------------------------------------------

    @property
    def rid(self):
        return self.req.rid

    @property
    def status(self) -> str:
        """queued | prefill | decoding | done | cancelled | shed"""
        return self.req.status

    @property
    def done(self) -> bool:
        return self.req.status in ("done", "cancelled", "shed")

    # -- streaming ------------------------------------------------------------

    def tokens(self) -> Iterator[int]:
        """Stream this request's generated tokens as they land. Raises
        :class:`DeadlineExceeded` if the request was (or gets) shed."""
        while True:
            if self.ring and len(self.ring):
                self._emitted += 1
                yield self.ring.pop()
                continue
            if self.req.status == "shed":
                raise DeadlineExceeded(self.req.rid, self.req.deadline_ms)
            if self.done:
                return
            self.engine.step()

    def __iter__(self) -> Iterator[int]:
        return self.tokens()

    def result(self) -> "object":
        """Drain to completion; returns the finished Request (``.out`` holds
        every generated token, including any already streamed)."""
        for _ in self.tokens():
            pass
        return self.req

    def cancel(self):
        """Abandon the request in whatever state it is in: queued requests
        leave the queue, mid-prefill rows are voided (their chunk rows keep
        computing garbage — static shapes — but never claim a slot),
        decoding slots finalize their partial output and recycle."""
        self.engine.cancel(self.req)
