"""Replica transport seam of the multi-replica serving fabric.

The router and fleet never touch a :class:`~repro.serve.ServeEngine`
directly — every interaction goes through a :class:`ReplicaTransport`, so
the SAME router/migration/scaling logic drives in-process replicas (this
module's :class:`InProcessTransport`, the Tier-1-testable default: a
4-replica fleet is four engines in one process) and, later, remote
replicas behind an RPC boundary.

Fail-stop semantics are the paper's: a killed replica loses ALL state —
:meth:`InProcessTransport.kill` drops the engine object outright, and
every subsequent call raises :class:`ReplicaDead`. Recovery therefore
cannot read anything back from the dead replica; the router's own
request census (what it dispatched, which tokens streamed back) is the
only recovery input — which is exactly what makes the recovery cost
independent of the work the replica had already performed.
"""
from __future__ import annotations

from typing import Optional

from repro.serve.engine import ServeEngine


class ReplicaDead(RuntimeError):
    """Raised by every call on a fail-stopped replica transport. The
    fleet treats it exactly like a missed heartbeat: mark the replica
    DEAD and migrate its in-flight requests."""

    def __init__(self, replica_id, op: str = "call"):
        self.replica_id, self.op = replica_id, op
        super().__init__(
            f"replica {replica_id} is dead (fail-stop): {op} refused")


class ReplicaTransport:
    """Abstract seam between the router and one replica's engine.

    Implementations must preserve two contracts the fleet builds on:

      * **fail-stop, not fail-slow** — after :meth:`kill` (or a real
        crash) every method raises :class:`ReplicaDead`; no call may
        return stale data from a dead replica.
      * **engine-compatible streaming** — :meth:`submit` returns the
        engine's :class:`~repro.serve.scheduler.RequestHandle`, whose
        :class:`~repro.serve.scheduler.TokenRing` the router drains after
        each step; token order is the engine's emission order.
    """

    replica_id: int = -1

    def submit(self, req):
        """Dispatch a (shadow) request to the replica's engine; returns
        the engine-level handle whose ring the router drains."""
        raise NotImplementedError

    def cancel(self, req):
        raise NotImplementedError

    def step(self, failed_group: Optional[int] = None) -> int:
        """Advance the replica's engine one step; returns active slots."""
        raise NotImplementedError

    def heartbeat(self) -> bool:
        """Health probe. True = alive; False / :class:`ReplicaDead` =
        fail the replica."""
        raise NotImplementedError

    def idle(self) -> bool:
        raise NotImplementedError

    def metrics(self) -> dict:
        raise NotImplementedError

    def kill(self):
        """Inject a fail-stop: all replica state is lost, every later
        call raises :class:`ReplicaDead`."""
        raise NotImplementedError

    def warm_state(self) -> Optional[dict]:
        """Shareable startup state (census / compiled plans / quantized
        weights / autotune winners) for spawning sibling replicas of
        identical config without re-running startup work. ``None`` when
        the transport cannot share it (e.g. across a process boundary)."""
        return None


class InProcessTransport(ReplicaTransport):
    """A replica as an in-process :class:`ServeEngine` — the seam's
    default implementation and the one Tier-1 tests drive: a whole fleet
    lives in one process, and :meth:`kill` simulates a machine loss by
    dropping the engine (state unrecoverable) and poisoning the seam.

    ``warm`` is a sibling engine's :meth:`ServeEngine.warm_state`: a
    spawned replica of identical config reuses the shared census /
    compiled plans / quantized weights / autotune winners instead of
    re-running startup work (the fleet's scale-up path)."""

    def __init__(self, cfg, scfg, params, *, replica_id: int = 0,
                 warm: Optional[dict] = None):
        self.replica_id = replica_id
        self._dead = False
        self.engine: Optional[ServeEngine] = ServeEngine(
            cfg, scfg, params, warm=warm)

    def _live(self, op: str) -> ServeEngine:
        if self._dead or self.engine is None:
            raise ReplicaDead(self.replica_id, op)
        return self.engine

    def submit(self, req):
        return self._live("submit").submit(req)

    def cancel(self, req):
        self._live("cancel").cancel(req)

    def step(self, failed_group: Optional[int] = None) -> int:
        return self._live("step").step(failed_group=failed_group)

    def heartbeat(self) -> bool:
        self._live("heartbeat")
        return True

    def idle(self) -> bool:
        return self._live("idle").idle()

    def metrics(self) -> dict:
        return dict(self._live("metrics").metrics)

    def warm_state(self) -> Optional[dict]:
        return self._live("warm_state").warm_state()

    def kill(self):
        # fail-stop: the engine (cache, slots, in-flight admission state)
        # is GONE — recovery must work from the router's census alone
        self._dead = True
        self.engine = None
