"""Per-slot reference serving engine — the pre-batching baseline.

This is the seed engine's control flow (one batch-1 jitted decode call per
active slot per engine step), kept as a first-class reference:

  * the batched :class:`repro.serve.engine.ServeEngine` must produce
    bit-identical greedy outputs to this engine (tested in
    tests/test_serve_engine.py);
  * benchmarks/serve_throughput.py uses it as the throughput baseline the
    batched engine is measured against.

Differences from the seed version (cleanups that do not change outputs):
the dead never-read engine-level cache is gone, prefill always starts from
one shared zeroed slot-cache template (slot recycling is explicit — a
recycled slot can never see the previous tenant's KV or recurrent state),
and generation stops after exactly ``max_new`` tokens instead of decoding
one extra token and truncating.

Fault tolerance is NOT implemented here: protecting one slot at a time is
pointless (recovery needs M live groups in the same GEMM), which is exactly
why the batched engine exists. ``ft_mode`` must be ``"none"``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.api import get_model
from repro.serve.engine import Request, ServeConfig


class PerSlotEngine:
    def __init__(self, cfg: ModelConfig, scfg: ServeConfig, params):
        if scfg.ft_mode != "none":
            raise ValueError(
                "PerSlotEngine is the unprotected baseline; entangled "
                "serving needs the batched ServeEngine (M groups must share "
                "one GEMM)")
        self.cfg, self.scfg, self.params = cfg, scfg, params
        self.model = get_model(cfg)
        B, S = scfg.max_batch, scfg.max_seq
        self.slots: list[Optional[dict]] = [None] * B
        self.queue: list[Request] = []
        self.done: list[Request] = []
        self._prefill = jax.jit(
            lambda p, b, c: self.model.prefill(p, b, self.cfg, c))
        self._decode = jax.jit(
            lambda p, t, c, pos: self.model.decode_step(p, t, c, pos, self.cfg))
        # one shared zero template: prefill is functional, so every admit
        # starts from pristine state (explicit recycling, no stale KV)
        self._fresh_slot = self.model.init_cache(cfg, 1, S)
        self.decode_calls = 0  # jitted decode invocations (A/B observability)

    def submit(self, req: Request):
        need = len(req.prompt) + req.max_new
        if need > self.scfg.max_seq:  # same capacity contract as ServeEngine
            raise ValueError(
                f"request rid={req.rid} needs {need} positions "
                f"> max_seq={self.scfg.max_seq}")
        self.queue.append(req)

    def _sample(self, logits: jax.Array) -> int:
        return int(jnp.argmax(logits, -1))

    def _finish(self, i: int):
        s = self.slots[i]
        req = s["req"]
        req.out = np.asarray(s["toks"][: req.max_new], np.int32)
        self.done.append(req)
        self.slots[i] = None  # recycled: next admit starts from _fresh_slot

    def step(self) -> int:
        """One engine step: admit + prefill new requests, then one batch-1
        decode call PER active slot. Returns the number of active slots."""
        for i in range(len(self.slots)):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                tokens = jnp.asarray(req.prompt[None, :].astype(np.int32))
                logits, cache = self._prefill(
                    self.params, {"tokens": tokens}, self._fresh_slot)
                self.slots[i] = {
                    "req": req, "cache": cache, "pos": len(req.prompt),
                    "toks": [self._sample(logits[0])],
                }
                if req.max_new <= 1:
                    self._finish(i)
        active = [i for i, s in enumerate(self.slots) if s is not None]
        for i in active:
            s = self.slots[i]
            tok_in = jnp.asarray([[s["toks"][-1]]], dtype=jnp.int32)
            logits, s["cache"] = self._decode(
                self.params, tok_in, s["cache"], s["pos"])
            self.decode_calls += 1
            s["pos"] += 1
            s["toks"].append(self._sample(logits[0]))
            if len(s["toks"]) >= s["req"].max_new:
                self._finish(i)
        return sum(s is not None for s in self.slots)

    def run_to_completion(self, max_steps: int = 1000) -> list[Request]:
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self.step()
            steps += 1
        return self.done
