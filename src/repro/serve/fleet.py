"""Multi-replica serving fleet: replica pool, heartbeat health checks,
fail-stop migration, and queue-depth autoscaling.

This is the layer the ROADMAP calls entanglement ABOVE the engine: each
:class:`~repro.serve.ServeEngine` already rolls forward past a failed
in-kernel stream group; the :class:`Fleet` rolls forward past a failed
whole REPLICA — lose a machine, keep every request — with recovery cost
independent of the work the dead replica had already performed (one
batched prefill of each request's generated prefix; see
:mod:`repro.serve.router`).

Structure (Ray Serve's router / replica-state / backpressure split is the
design exemplar):

  * :class:`Replica` — one engine behind a
    :class:`~repro.serve.transport.ReplicaTransport`, with the lifecycle
    STARTING -> HEALTHY -> DRAINING -> DEAD. STARTING replicas take no
    traffic until their first heartbeat; DRAINING replicas finish what
    they hold and retire; DEAD is terminal (either a graceful retire or a
    fail-stop, distinguished by ``failed``).
  * :class:`Fleet` — owns the pool and the step loop. One
    :meth:`Fleet.step` = heartbeats -> shed -> dispatch -> one engine
    step on every live replica -> token drain -> retire idle drainers ->
    autoscale. Everything is driven by the injectable ``ServeConfig.clock``
    and plain step counting, so a 4-replica fleet with a mid-decode kill
    is a deterministic single-process Tier-1 test.
  * :class:`ScalingPolicy` — spawns replicas when router queue depth
    outruns the healthy pool and drains one when the queue is empty and
    per-replica utilization (packed prompt tokens against the token
    budget, or slot occupancy) falls below a floor.

Spawned replicas reuse the first replica's
:meth:`~repro.serve.ServeEngine.warm_state` — shared slot census,
:class:`~repro.ft.plans.CompiledPlans`, quantized protected weights and
autotune winners — so scale-up under load costs engine construction, not
a startup re-sweep (``plans.misses == 0`` and zero new autotune sweeps on
every replica after the first).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from repro.serve.engine import Request, ServeConfig
from repro.serve.router import Router
from repro.serve.scheduler import RequestHandle
from repro.serve.transport import (InProcessTransport, ReplicaDead,
                                   ReplicaTransport)

STARTING, HEALTHY, DRAINING, DEAD = "starting", "healthy", "draining", "dead"


@dataclasses.dataclass
class Replica:
    """One pool member: a transport plus its lifecycle state. The fleet
    is the only writer; the router only reads ``rid``/``transport``."""

    rid: int
    transport: ReplicaTransport
    state: str = STARTING
    failed: bool = False  # DEAD via fail-stop (vs graceful retirement)
    active: int = 0  # slots active at the last step (occupancy signal)
    packed_seen: int = 0  # packed_tokens counter at the last scale decision
    steps_seen: int = 0  # fleet steps this replica was live since then

    @property
    def live(self) -> bool:
        return self.state in (STARTING, HEALTHY, DRAINING)

    def utilization(self, scfg: ServeConfig, packed_now: int) -> float:
        """Fraction of serving capacity used since the last scaling
        decision: packed prompt tokens against the per-step token budget
        when token packing is on, slot occupancy otherwise."""
        if self.steps_seen <= 0:
            return 1.0  # no observation window yet — never a drain signal
        if scfg.token_budget > 0:
            return ((packed_now - self.packed_seen)
                    / (self.steps_seen * scfg.token_budget))
        return self.active / max(scfg.max_batch, 1)


@dataclasses.dataclass
class ScalingPolicy:
    """Queue-depth / utilization autoscaling. Pure policy: ``decide``
    looks at the router queue and per-replica utilization and returns
    +1 (spawn), -1 (drain one) or 0 — the fleet applies the decision and
    enforces the [min_replicas, max_replicas] bounds."""

    min_replicas: int = 1
    max_replicas: int = 4
    scale_up_depth: int = 4  # queued requests PER HEALTHY replica
    scale_down_util: float = 0.25  # drain when every replica is below this
    decide_every: int = 8  # fleet steps between decisions

    def __post_init__(self):
        if self.min_replicas < 1:
            raise ValueError(
                f"min_replicas must be >= 1, got {self.min_replicas}")
        if self.max_replicas < self.min_replicas:
            raise ValueError(
                f"max_replicas ({self.max_replicas}) < min_replicas "
                f"({self.min_replicas})")
        if self.decide_every < 1:
            raise ValueError(
                f"decide_every must be >= 1, got {self.decide_every}")

    def decide(self, queue_depth: int, healthy: int,
               utils: List[float]) -> int:
        if healthy < self.min_replicas:
            return 1
        if (queue_depth > self.scale_up_depth * max(healthy, 1)
                and healthy < self.max_replicas):
            return 1
        if (healthy > self.min_replicas and queue_depth == 0 and utils
                and max(utils) < self.scale_down_util):
            return -1
        return 0


@dataclasses.dataclass
class FleetConfig:
    """Fleet-level knobs, separate from the per-engine ``ServeConfig``
    (which every replica shares, minus router-owned admission fields)."""

    replicas: int = 1  # initial pool size
    heartbeat_every: int = 1  # fleet steps between health probes
    policy: Optional[ScalingPolicy] = None  # None = fixed-size pool
    transport_factory: Callable[..., ReplicaTransport] = InProcessTransport

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        if self.heartbeat_every < 1:
            raise ValueError(
                f"heartbeat_every must be >= 1, got {self.heartbeat_every}")


class Fleet:
    """N-replica serving fabric behind the single-engine surface:
    ``submit() -> RequestHandle``, ``step()``, ``cancel()``, ``idle()``,
    ``run_to_completion()`` — drop-in for :class:`ServeEngine` in every
    caller, including :class:`~repro.serve.scheduler.RequestHandle`
    itself (handle iteration drives ``Fleet.step``)."""

    def __init__(self, cfg, scfg: ServeConfig, params,
                 fcfg: Optional[FleetConfig] = None):
        self.cfg, self.params = cfg, params
        self.fcfg = fcfg or FleetConfig()
        self.scfg = scfg
        # replicas never shed or reject: admission control (max_queue,
        # deadlines, EDF) lives in the router — the fleet's one gatekeeper
        self.rep_scfg = dataclasses.replace(scfg, max_queue=0)
        self.router = Router(self, scfg)
        self.replicas: Dict[int, Replica] = {}
        self._next_rid = 0
        self._warm: Optional[dict] = None
        self.steps = 0
        self.metrics = {"spawned": 0, "retired": 0, "failed": 0,
                        "scale_ups": 0, "scale_downs": 0}
        for _ in range(self.fcfg.replicas):
            self._spawn()

    # -- pool management ------------------------------------------------------

    def _spawn(self) -> Replica:
        """Add a replica. The first one pays full engine startup (census
        trace, plan compilation, weight quantization, autotune sweep) and
        publishes its warm state; every later spawn reuses it, so scale-up
        never re-sweeps."""
        rid = self._next_rid
        self._next_rid += 1
        tr = self.fcfg.transport_factory(
            self.cfg, self.rep_scfg, self.params,
            replica_id=rid, warm=self._warm)
        if self._warm is None:
            self._warm = tr.warm_state()
        rep = Replica(rid=rid, transport=tr)
        self.replicas[rid] = rep
        self.metrics["spawned"] += 1
        return rep

    def _fail(self, rep: Replica):
        """Declare a replica fail-stopped: terminal state, then migrate
        every request the router had assigned to it."""
        if rep.state == DEAD:
            return
        rep.state, rep.failed = DEAD, True
        self.metrics["failed"] += 1
        self.router.migrate(rep.rid)

    def kill_replica(self, rid: int):
        """Inject a fail-stop (test/bench hook): the transport drops all
        replica state; the next heartbeat (same step) detects and
        migrates. Killing the last live replica is allowed — requests
        wait in the router queue until a spawn or scale-up revives the
        pool, exactly like a real full outage."""
        self.replicas[rid].transport.kill()

    def transport_of(self, rid: int) -> Optional[ReplicaTransport]:
        rep = self.replicas.get(rid)
        return rep.transport if rep is not None and rep.live else None

    def _healthy(self) -> List[Replica]:
        return [r for r in self.replicas.values() if r.state == HEALTHY]

    # -- step loop ------------------------------------------------------------

    def _heartbeats(self):
        for rep in self.replicas.values():
            if not rep.live:
                continue
            try:
                ok = rep.transport.heartbeat()
            except ReplicaDead:
                ok = False
            if not ok:
                self._fail(rep)
            elif rep.state == STARTING:
                rep.state = HEALTHY  # first successful probe promotes

    def step(self, failed_group: Optional[int] = None) -> int:
        """One fleet step: health, admission, one engine step per live
        replica, token drain, retirement, scaling. Returns total active
        slots across live replicas (the engine-step contract).
        ``failed_group`` is forwarded to every replica — the in-engine
        stream-group fail-stop and the fleet-level replica fail-stop
        compose."""
        self.steps += 1
        if (self.steps - 1) % self.fcfg.heartbeat_every == 0:
            self._heartbeats()
        self.router.shed()
        self.router.dispatch(self._healthy())
        active_total = 0
        for rep in list(self.replicas.values()):
            if not rep.live:
                continue
            try:
                rep.active = rep.transport.step(failed_group=failed_group)
            except ReplicaDead:
                self._fail(rep)
                continue
            rep.steps_seen += 1
            active_total += rep.active
        self.router.drain()
        self._retire_drained()
        if self.fcfg.policy is not None and (
                self.steps % self.fcfg.policy.decide_every == 0):
            self._autoscale()
        return active_total

    def _retire_drained(self):
        for rep in self.replicas.values():
            if rep.state != DRAINING:
                continue
            try:
                done = (self.router.assigned(rep.rid) == 0
                        and rep.transport.idle())
            except ReplicaDead:
                continue  # heartbeat will fail it
            if done:
                rep.state = DEAD
                self.metrics["retired"] += 1

    def _autoscale(self):
        pol = self.fcfg.policy
        healthy = self._healthy()
        utils = []
        for rep in healthy:
            try:
                packed = rep.transport.metrics().get("packed_tokens", 0)
            except ReplicaDead:
                continue
            utils.append(rep.utilization(self.rep_scfg, packed))
            rep.packed_seen, rep.steps_seen = packed, 0
        d = pol.decide(len(self.router.queue), len(healthy), utils)
        if d > 0 and len(healthy) < pol.max_replicas:
            self._spawn()
            self.metrics["scale_ups"] += 1
        elif d < 0 and len(healthy) > pol.min_replicas:
            # drain the least-loaded healthy replica; it takes no new
            # work and retires once its in-flight requests finish
            rep = min(healthy, key=lambda r: (self.router.load(r.rid), r.rid))
            rep.state = DRAINING
            self.metrics["scale_downs"] += 1

    # -- engine-compatible surface --------------------------------------------

    def submit(self, req: Request) -> RequestHandle:
        return self.router.submit(req)

    def cancel(self, req: Request):
        self.router.cancel(req)

    def idle(self) -> bool:
        return self.router.idle()

    def run_to_completion(self, max_steps: int = 10_000,
                          failed_group: Optional[int] = None) -> int:
        """Step until every router-tracked request finishes. Returns the
        steps taken; raises if the fleet cannot drain (e.g. every replica
        dead with an empty scaling policy)."""
        for n in range(max_steps):
            if self.idle():
                return n
            self.step(failed_group=failed_group)
        if not self.idle():
            raise RuntimeError(
                f"fleet did not drain within {max_steps} steps "
                f"({len(self.router.records)} live records, "
                f"{len(self.router.queue)} queued, "
                f"{len(self._healthy())} healthy replicas)")
        return max_steps

    def fleet_metrics(self) -> dict:
        """Aggregated observability: fleet counters + router counters +
        per-replica state/engine metrics."""
        out = dict(self.metrics)
        out.update({f"router_{k}": v for k, v in self.router.metrics.items()})
        per = {}
        for rid, rep in self.replicas.items():
            entry = {"state": rep.state, "failed": rep.failed}
            if rep.live:
                try:
                    entry["engine"] = rep.transport.metrics()
                except ReplicaDead:
                    pass
            per[rid] = entry
        out["replicas"] = per
        return out
