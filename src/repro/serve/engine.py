"""Batched continuous-batching serving engine with the entangled logits
head on the real hot path — decode AND admission.

One engine step issues ONE jitted decode call over the whole slot pool:

  * the KV/recurrent cache is slot-batched — a single pytree with batch
    dim ``max_batch``, every slot one row;
  * each slot decodes at its own position (the model decode contract takes
    an int32 position VECTOR [B]); admission and eviction only flip values
    in the position/active arrays, never shapes, so the decode program
    compiles once and is never retraced as traffic churns;
  * slot recycling is explicit: finished slots' cache rows are zeroed (one
    batched scatter per step, not one insert per request), so no tenant can
    observe a predecessor's KV or recurrent state.

Admission is a bucketed, chunked batched prefill pipeline (NOT one batch-1
call per request):

  * queued prompts are padded to a small geometric set of length buckets
    (``ServeConfig.prefill_buckets``; default 8, 16, 32, ..., max_seq) and
    all same-bucket admits prefill in ONE batched [Bp, T_bucket] call via
    the model's ``prefill_chunk`` contract (per-row true lengths keep
    rolling-window and recurrent caches exact under padding) — the prefill
    program retraces at most once per (bucket, chunk) shape, never per
    prompt length;
  * long prompts are split into fixed-size chunks
    (``ServeConfig.prefill_chunk``; Sarathi/vLLM-style): each engine step
    advances the pending admission by ONE chunk and still runs the full
    decode step, so decode latency stays flat while a long prompt batch is
    being admitted;
  * the whole admission batch's filled caches are scattered into their
    slots in ONE jitted batched row scatter; the first generated tokens
    come from the gathered per-row last-prompt hidden states.

Steady-state serving (mid-flight refill + async frontend + deadlines):

  * **mid-flight refill** (``ServeConfig.refill``, default on): the moment
    a slot finishes (``max_new`` reached, EOS, cancel) it is recycled into
    the LIVE prefill chunk stream — the engine plans a new admission batch
    over freed slots while other batches are still mid-chunk, instead of
    waiting for the current wave to drain to a bucket boundary. Several
    admission batches can be in flight at once (``_inflight``); each still
    runs the census'd ``[Bp, bucket]`` chunk programs with the same static
    shapes, so refill NEVER retraces and never creates a plan-registry
    entry (asserted at runtime via ``CompiledPlans.misses``). Slot ->
    group stays ``slot % M`` — group assignment is positional, plans are
    keyed by (site, shape), and activation quantization is per row
    (:mod:`repro.ft.quantize`), so WHEN a slot was refilled can never move
    another request's integer grid: the entangled roll-forward is
    bit-identical under refill and boundary admission alike (tested as a
    refill x fail-stop matrix).
  * **async frontend**: ``submit()`` returns a
    :class:`~repro.serve.scheduler.RequestHandle` — iterate it to stream
    tokens from a per-request ring buffer as decode steps land, call
    ``cancel()`` in any state, set ``Request.deadline_ms`` for an SLA.
  * **deadline-aware chunk scheduling**
    (:class:`~repro.serve.scheduler.ChunkScheduler`): admission batches
    form and advance earliest-deadline-first; decode is never starved more
    than ``max_prefill_per_step`` chunks per step; ``max_queue`` bounds
    the wait queue with a typed :class:`AdmissionRejected` at saturation,
    and queued requests whose deadline lapses are shed loudly before any
    prefill compute is spent on them (``metrics`` records all of it).
  * recycled-row zeroing and admission inserts share ONE batched scatter:
    a landing chunk's ``_scatter_rows`` call carries the pending zero rows
    in its spare capacity (``zero`` mask), so a steady-state step costs a
    single scatter — free rows are always zeroed again before the next
    decode, exactly as under boundary admission.

Token-packed admission (``ServeConfig.token_budget > 0``): the per-batch
``[Bp, bucket]`` chunk programs are replaced by ONE fixed-shape
token-parallel program per step — each step gathers up to ``token_budget``
prompt tokens from ALL in-flight admission batches (scheduler-ordered:
EDF + shortest-remaining-prefill, :meth:`ChunkScheduler.pack_rows`) as
``token_budget / prefill_chunk`` rows of ``prefill_chunk`` tokens, each
row one request's next chunk with per-row (slot, pos0, length) metadata:

  * rows advance to the request's TRUE prompt length — bucket padding is
    never packed, so the packed program runs denser than the bucketed
    chunk pipeline it replaces (the FT codec cost per true token drops
    with packing density);
  * per-slot cache state is gathered/scattered by the row metadata from a
    slot-indexed STAGING cache; ragged co-resident rows attend through
    per-row absolute-position masks (``attend_prefill_packed``) and the
    rolling-window / Mamba / RG-LRU recurrences carry per-slot state the
    same way, so a fresh row at offset 0 co-packs with a mid-prompt row
    bit-exactly;
  * the program is padded to the budget — exactly ONE compiled
    ``[Rp, Cp]`` shape regardless of the packing mix (mixed buckets,
    ragged tails, cancels), so ``CompiledPlans.misses`` stays 0 for any
    traffic, same as refill;
  * FT transparency is structural: slot -> group stays ``slot % M``,
    activation quantization is per row, and the entangled roll-forward is
    exact — packed admission is bit-identical to per-batch chunking under
    fail-stop injection in every group (tested as a packed x arch x scope
    x failed-group matrix).

Fault tolerance (the paper's technique in the serving path): with
``ft_mode='entangle'`` the final logits projection of EVERY decode step —
and of every admission batch's first token — runs as the fused entangled
int8 GEMM over M request groups (repro.ft.heads), slots mapped round-robin
to groups (slot -> group = slot % M). ``ServeConfig.ft_scope`` widens the
protection beyond the head through the unified protected-GEMM subsystem
(:mod:`repro.ft`): ``"qkv"`` additionally runs the mixer input projections
(attention Q/K/V, Mamba in_proj, RG-LRU in_x/in_gate) entangled, ``"mlp"``
the FFN projections (MLP gate/up/down, MoE router), ``"out"`` the mixer
output projections (attention/MLA wo, Mamba out_proj, RG-LRU out),
``"moe"`` the MoE per-expert GEMMs (the grouped entangled kernel), and
``"all"`` every protected site — on the decode hot path AND inside every
prefill-admission chunk, where the QKV/MLP GEMMs dominate the FLOP budget.
Protection parameters are compiled AHEAD OF TIME: the startup census is
frozen into immutable per-site ProtectionPlans (``repro.ft.compile_plans``)
and every in-model site's weights are int8-quantized once at startup
(``repro.ft.prepare_params``), so traced steps only look up plans and
never re-quantize weights.
``step(failed_group=r)`` injects a fail-stop into group r's compute at
every protected site of the step; the in-kernel roll-forward recovers its
outputs from the other M-1 groups' entangled accumulators, so decoded
tokens are bit-identical with and without the failure — no request
observes it, at any scope.

Autotune warmup contract: with ``blocks='auto'`` the engine sweeps the head
GEMM's block sizes at startup (``warm_autotune``) for its decode AND
prefill-admission shape census, so the in-jit ``blocks='auto'`` resolution
is a pure cache hit — sweeps must never run inside a traced decode step or
a traced prefill.

On hosts with more than one device the decode step traces under
``dist.sharding.serve_mesh()``, sharding the slot batch (and the head GEMM)
across devices.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.plan import make_plan
from repro.dist import sharding
from repro.ft import (SCOPES, FTContext, PlanRegistry, compile_plans,
                      prepare_params)
from repro.ft.heads import (ft_logits_decode, ft_logits_prefill,
                            quantize_head)
from repro.kernels import ops as kops
from repro.kernels.codec import pack_int8
from repro.models.api import get_model
from repro.models.layers import ACT_DTYPE
from repro.models.transformer import readout_scale
from repro.serve.scheduler import (ChunkScheduler, RequestHandle, TokenRing)


def geometric_buckets(max_seq: int, base: int = 8) -> tuple:
    """Default prefill length buckets: powers of two from ``base`` up,
    capped with ``max_seq`` itself — a small set, so the batched prefill
    retraces a handful of times total, never per prompt length."""
    out = []
    b = base
    while b < max_seq:
        out.append(b)
        b *= 2
    out.append(max_seq)
    return tuple(out)


def resolve_buckets(scfg: "ServeConfig") -> tuple:
    """The admission bucket set a ServeConfig implies — shared by the
    engine and the fleet router, which must validate prompt capacity and
    plan migration resumes against the same bounds WITHOUT building an
    engine of its own."""
    buckets = tuple(sorted(set(
        int(b) for b in (scfg.prefill_buckets
                         or geometric_buckets(scfg.max_seq)))))
    if buckets[0] < 1 or buckets[-1] > scfg.max_seq:
        raise ValueError(
            f"prefill_buckets {buckets} must lie in [1, "
            f"max_seq={scfg.max_seq}]")
    return buckets


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4  # slot count; must be divisible by ft_M if entangling
    max_seq: int = 256
    ft_mode: str = "none"  # none | entangle
    ft_M: int = 4
    ft_w: int = 32
    # protected-GEMM scope: head | qkv | mlp | out | moe | all
    # (repro.ft.SCOPES) — which projections beyond the head run entangled
    ft_scope: str = "head"
    # store protected q8 weights int8-packed 4-per-int32-word (kernels
    # unpack on load): 4x fewer protected-weight HBM bytes per step.
    # False keeps the legacy int32-container copies (A/B baseline).
    ft_packed: bool = True
    # share one quantize+group codec pass across fanout site groups
    # (attention Q/K/V, MLP gate/up, ...); census marks groups either way
    ft_chain: bool = True
    greedy: bool = True
    # head-GEMM block sizes: None | dict | "auto" (autotuned at startup)
    blocks: Optional[object] = None
    use_pallas: bool = True  # entangled head via Pallas (False: XLA einsum)
    # -- admission (bucketed, chunked batched prefill) -----------------------
    prefill_buckets: Optional[Sequence[int]] = None  # None = geometric set
    prefill_chunk: int = 0  # >0: chunk prompts, one chunk per engine step
    prefill_batch: int = 0  # admission batch rows; 0 = max_batch
    # token-packed admission: > 0 packs up to token_budget prompt tokens
    # per step from ALL in-flight admission batches into ONE fixed-shape
    # [token_budget // prefill_chunk, prefill_chunk] token-parallel
    # program (requires prefill_chunk > 0, token_budget a multiple of it,
    # and rows <= max_batch). 0 = legacy per-batch [Bp, bucket] chunking.
    token_budget: int = 0
    # -- steady-state scheduling (repro.serve.scheduler) ---------------------
    # mid-flight refill: plan new admission batches over freed slots while
    # earlier batches are still mid-chunk. False = boundary mode (one
    # admission batch at a time — the legacy A/B baseline).
    refill: bool = True
    # chunked mode: prefill chunks advanced per step before the decode call
    # (decode is never starved more); unchunked admission ignores it
    max_prefill_per_step: int = 1
    max_queue: int = 0  # wait-queue bound; submit raises past it. 0 = off
    # injectable monotonic clock (seconds) for deadlines/latency metrics;
    # None = time.monotonic. Tests pass a fake for determinism.
    clock: Optional[Callable[[], float]] = None


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [T] int32
    max_new: int = 16
    out: Optional[np.ndarray] = None
    # SLA: shed from the wait queue (loudly — iterating the handle raises
    # DeadlineExceeded) if not admitted within deadline_ms of submit.
    # None = no deadline (ranks last in the EDF chunk schedule, FIFO).
    deadline_ms: Optional[float] = None
    eos_token: Optional[int] = None  # greedy-decoded EOS ends the request
    # -- engine-owned runtime state (set by submit/step, not the caller) ----
    # queued | prefill | decoding | done | cancelled | shed
    status: str = "new"
    t_submit: float = 0.0
    t_first: Optional[float] = None  # first-token wall time (TTFT source)
    t_done: Optional[float] = None
    tok_times: list = dataclasses.field(default_factory=list)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, scfg: ServeConfig, params,
                 warm: Optional[dict] = None):
        self.cfg, self.scfg, self.params = cfg, scfg, params
        if not scfg.greedy:
            raise NotImplementedError("only greedy decode is implemented")
        if warm is not None and warm.get("sig") != self._warm_sig():
            # a mismatched warm state would silently serve stale plans /
            # quantized weights for a DIFFERENT program set — refuse
            raise ValueError(
                "warm state was built by a differently-configured engine; "
                "replicas sharing startup products must share (cfg, scfg "
                "modulo clock)")
        self.model = get_model(cfg)
        B, S = scfg.max_batch, scfg.max_seq
        # THE slot-batched cache: one pytree, slot i = batch row i
        self.cache = self.model.init_cache(cfg, B, S)
        self.slots: list[Optional[dict]] = [None] * B
        self.queue: list[Request] = []
        self.done: list[Request] = []
        self.pos = np.zeros(B, np.int32)  # per-slot next decode position
        self.last_tok = np.zeros(B, np.int32)
        self.census: dict[str, dict] = {"prefill": {}, "decode": {}}
        self.decode_calls = 0  # jitted decode invocations (one per step)
        self.prefill_calls = 0  # jitted prefill invocations (chunk/packed)
        self.mesh = sharding.serve_mesh()

        # admission pipeline configuration
        self.buckets = resolve_buckets(scfg)
        if scfg.prefill_chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0, got "
                             f"{scfg.prefill_chunk}")
        if scfg.token_budget < 0:
            raise ValueError(f"token_budget must be >= 0, got "
                             f"{scfg.token_budget}")
        if scfg.token_budget:
            # loud parse-time geometry checks: the packed program has ONE
            # compiled [Rp, Cp] shape, so the budget must tile exactly into
            # chunk-wide rows and every row must map to a distinct slot
            if not scfg.prefill_chunk:
                raise ValueError(
                    f"token_budget={scfg.token_budget} requires "
                    f"prefill_chunk > 0 (rows are prefill_chunk tokens "
                    f"wide)")
            if scfg.token_budget % scfg.prefill_chunk:
                raise ValueError(
                    f"token_budget={scfg.token_budget} must be a multiple "
                    f"of prefill_chunk={scfg.prefill_chunk}")
            if scfg.token_budget // scfg.prefill_chunk > B:
                raise ValueError(
                    f"token_budget={scfg.token_budget} / prefill_chunk="
                    f"{scfg.prefill_chunk} = "
                    f"{scfg.token_budget // scfg.prefill_chunk} packed "
                    f"rows > max_batch={B} (each row stages in a distinct "
                    f"slot)")
        # packed geometry: Rp rows x Cp tokens; Rp == 0 means legacy
        self.Rp = (scfg.token_budget // scfg.prefill_chunk
                   if scfg.token_budget else 0)
        self.Cp = scfg.prefill_chunk
        self.Bp = scfg.prefill_batch or B
        if not 1 <= self.Bp <= B:
            # the batched row scatter maps every admission row to a DISTINCT
            # slot (pad rows write back the slot's own content), which needs
            # Bp <= max_batch; rows beyond the slot pool could never land
            raise ValueError(
                f"prefill_batch={self.Bp} must be in [1, max_batch={B}]")
        # zero admission-batch template: prefill start state AND the zeros
        # source for batched slot recycling (invariant: every free slot's
        # row is zeroed again before the next decode call)
        self._fresh_prefill = self.model.init_cache(cfg, self.Bp, S)
        if self.Rp:
            # token-packed staging: a slot-indexed cache (row i = slot i,
            # same layout as the decode pool) holding every in-flight
            # row's mid-prefill state; packed calls gather/scatter rows
            # by slot id. Fresh rows (pos0 == 0) are zeroed IN-PROGRAM,
            # so recycled staging rows never need host-side zeroing.
            self._pack_cache = self.model.init_cache(cfg, B, S)
            self._pack_hlast = jnp.zeros((B, cfg.d_model), ACT_DTYPE)
        self._inflight: list[dict] = []  # in-flight admission batches
        self._reserved: set[int] = set()  # slots claimed by in-flight rows
        self._dirty: list[int] = []  # freed slots awaiting batched zeroing
        self._rings: dict[int, TokenRing] = {}  # id(req) -> token ring
        self.scatter_calls = 0  # jitted _scatter_rows invocations
        self.sched = ChunkScheduler(
            max_prefill_per_step=scfg.max_prefill_per_step,
            max_queue=scfg.max_queue,
            clock=scfg.clock or time.monotonic)
        self._clock = self.sched.clock
        self.metrics = {"queue_depth_peak": 0, "rejected": 0, "shed": 0,
                        "refill_admissions": 0, "landings": 0,
                        "merged_zero_rows": 0, "cancelled": 0,
                        # token-packed admission accounting: TRUE prompt
                        # tokens packed (pad rows and intra-row padding
                        # excluded), packed program invocations, and the
                        # peak number of distinct admission batches
                        # co-packed into one program
                        "packed_tokens": 0, "packed_calls": 0,
                        "packed_batches_peak": 0}

        if scfg.ft_mode == "entangle":
            if B % scfg.ft_M:
                raise ValueError(
                    f"max_batch={B} must be divisible by ft_M={scfg.ft_M}")
            if scfg.ft_scope not in SCOPES:
                raise ValueError(
                    f"unknown ft_scope {scfg.ft_scope!r}; expected one of "
                    f"{sorted(SCOPES)}")
            if scfg.ft_scope != "head" and cfg.family == "encdec":
                raise ValueError(
                    "in-model protected GEMMs are decoder-only; enc-dec "
                    "supports ft_scope='head' only")
            if warm is not None:
                # fleet warm start: reuse the sibling replica's quantized
                # head and plan registry verbatim — same config, same
                # shapes, same grids
                self.plan = warm["plan"]
                self.head_q, self.w_scale = warm["head_q"], warm["w_scale"]
                self._head_dims = warm["head_dims"]
                self.registry = warm["registry"]
            else:
                # plan reuse: made ONCE, shared by every decode step, every
                # admission-batch head projection, every in-model protected
                # site and every autotune key
                self.plan = make_plan(scfg.ft_M, scfg.ft_w)
                self.head_q, self.w_scale = quantize_head(
                    self.model.head_weights(params, cfg))
                # true [D, V] head dims — recorded BEFORE packing (the
                # packed copy's contraction axis holds ceil(D/4) words,
                # not D)
                self._head_dims = tuple(self.head_q.shape)
                if scfg.ft_packed:
                    self.head_q = pack_int8(self.head_q, axis=0)
                # the protected-GEMM subsystem: one registry for the whole
                # forward pass; layer sites get "auto" blocks only when the
                # engine itself autotunes (a user dict targets the HEAD
                # shape and must not leak onto differently-shaped layer
                # GEMMs)
                self.registry = PlanRegistry(
                    self.plan,
                    blocks="auto" if scfg.blocks == "auto" else None,
                    packed=scfg.ft_packed)
            self.ftx = FTContext(registry=self.registry,
                                 scope=scfg.ft_scope,
                                 use_pallas=scfg.use_pallas,
                                 chain=scfg.ft_chain)
        elif scfg.ft_mode != "none":
            raise ValueError(f"unknown ft_mode {scfg.ft_mode!r}")
        self._head_blocks = self._default_head_blocks()

        # donate the slot-batched cache through decode/insert so XLA aliases
        # it in place instead of copying the engine's largest buffer every
        # token (donation is a no-op warning on CPU, so gate it)
        donate = jax.default_backend() != "cpu"
        self._scatter_rows = jax.jit(self._scatter_rows_impl,
                                     donate_argnums=(0,) if donate else ())
        # NO donation on chunk 0: it is fed the shared _fresh_prefill
        # template, which must survive every admission. Continuation
        # chunks exclusively own their cache/h_last carry — donate them.
        # failed_group is static like on the decode path: each injected
        # variant is its own program sharing plans and autotune winners
        # (always None when ft_scope == 'head', so no extra retraces).
        self._prefill_chunk = jax.jit(
            self._prefill_chunk_impl,
            static_argnames=("pos0", "failed_group"))
        self._prefill_chunk_cont = jax.jit(
            self._prefill_chunk_impl,
            static_argnames=("pos0", "failed_group"),
            donate_argnums=(2, 4) if donate else ())
        # the token-packed prefill step exclusively owns the staging
        # cache + h_last carry — donate both so XLA updates them in place
        self._prefill_packed = jax.jit(
            self._prefill_packed_impl,
            static_argnames=("failed_group",),
            donate_argnums=(1, 2) if donate else ())
        self._gather_rows = jax.jit(self._gather_rows_impl)
        self._prefill_head = jax.jit(self._prefill_head_impl,
                                     static_argnames=("failed_group",))
        self._decode = jax.jit(self._decode_impl,
                               static_argnames=("failed_group",),
                               donate_argnums=(1,) if donate else ())
        # startup plan compilation (the v2 AOT flow): prime the registry
        # with every protected shape the engine can trace (decode + all
        # chunk widths) via census-only abstract traces, freeze it into
        # immutable per-site ProtectionPlans, and hoist the eq.-13 int8
        # weight quantization of every in-model protected site out of the
        # traced graph — ``ft_params`` carries the startup-quantized q8
        # copies alongside the float masters, so a traced decode/prefill
        # step contains ZERO weight-quantization ops (tested via the
        # quantize.TRACE_STATS trace counter)
        if warm is not None:
            # fleet warm start: the census, compiled ProtectionPlans and
            # startup-quantized params are immutable after startup, so a
            # spawned replica of identical config reuses one copy —
            # NO census retrace, NO plan compile, NO eq.-13 weight
            # re-quantization, NO autotune sweep (tested: spawning the
            # second replica leaves quantize.TRACE_STATS and the autotune
            # sweep counter untouched). The shared CompiledPlans pools
            # its ``misses`` counter across the fleet.
            self.protected_census = warm["census"]
            self._chunk_widths = self._all_chunk_widths()
            self.plans = warm["plans"]
            self.ft_params = warm["ft_params"]
            if self.plans is not None:
                self.ftx = self.ftx.with_plans(self.plans)
            return
        self.protected_census = self._protected_shape_census()
        # every chunk width any admission — boundary or refill — can run:
        # refill-time plan reuse is checked against this set, because a
        # refilled batch replays one of exactly these census'd programs
        self._chunk_widths = self._all_chunk_widths()
        self.plans = None
        self.ft_params = params
        if scfg.ft_mode == "entangle" and scfg.ft_scope != "head":
            self.plans = compile_plans(self.registry, self.protected_census)
            # census / compile drift fails loudly at startup — a lazy
            # mid-serve plan entry would mean refill retraced a shape the
            # startup census missed
            self.plans.assert_covers(self.protected_census)
            self.ftx = self.ftx.with_plans(self.plans)
            self.ft_params = prepare_params(params, scope=scfg.ft_scope,
                                            packed=scfg.ft_packed)
        if scfg.blocks == "auto":
            self.warm_autotune()

    def _warm_sig(self) -> tuple:
        """Config signature warm-started replicas must share. The clock is
        excluded — it is the only per-process field and shapes no traced
        program."""
        return (self.cfg, dataclasses.replace(self.scfg, clock=None))

    def warm_state(self) -> dict:
        """Shareable startup products for spawning engine replicas of
        IDENTICAL config — the fleet's scale-up seam. The protected-site
        census, compiled :class:`~repro.ft.plans.CompiledPlans`,
        startup-quantized ``ft_params`` and the quantized head are all
        immutable after startup, so sibling replicas share one copy:
        constructing ``ServeEngine(cfg, scfg, params, warm=...)`` re-runs
        no census trace, no plan compile, no weight quantization and no
        autotune sweep. Sharing CompiledPlans also pools its ``misses``
        counter, so the fleet's ``misses == 0`` invariant covers every
        replica at once."""
        w = {"sig": self._warm_sig(), "census": self.protected_census,
             "plans": self.plans, "ft_params": self.ft_params}
        if self.scfg.ft_mode == "entangle":
            w.update(plan=self.plan, head_q=self.head_q,
                     w_scale=self.w_scale, head_dims=self._head_dims,
                     registry=self.registry)
        return w

    def submit(self, req: Request) -> RequestHandle:
        """Enqueue a request and return its async handle (iterate for the
        token stream; ``cancel()``; ``result()``). Raises
        :class:`~repro.serve.scheduler.AdmissionRejected` at saturation
        (``max_queue``) — a typed rejection, never a silent drop."""
        # loud capacity checks: past max_seq the vector cache scatter would
        # silently DROP K/V writes, and a prompt longer than the largest
        # bucket would either retrace per length or OOM the bucket planner —
        # both turn overflow into wrong tokens / stalls instead of an error
        if len(req.prompt) > self.buckets[-1]:
            raise ValueError(
                f"request rid={req.rid} prompt length {len(req.prompt)} > "
                f"largest prefill bucket {self.buckets[-1]} (configure "
                f"prefill_buckets / raise max_seq)")
        need = len(req.prompt) + req.max_new
        if need > self.scfg.max_seq:
            raise ValueError(
                f"request rid={req.rid} needs {need} positions "
                f"(prompt {len(req.prompt)} + max_new {req.max_new}) "
                f"> max_seq={self.scfg.max_seq}")
        try:
            self.sched.check_admission(req.rid, len(self.queue))
        except Exception:
            self.metrics["rejected"] += 1
            raise
        req.status = "queued"
        req.t_submit = self._clock()
        ring = TokenRing(req.max_new)
        self._rings[id(req)] = ring
        self.queue.append(req)
        self.metrics["queue_depth_peak"] = max(
            self.metrics["queue_depth_peak"], len(self.queue))
        return RequestHandle(self, req, ring)

    def _bucket_for(self, req: Request) -> int:
        """Smallest configured bucket covering the prompt."""
        n = len(req.prompt)
        for b in self.buckets:
            if n <= b:
                return b
        raise AssertionError("unreachable: submit() rejects oversize")

    def _default_head_blocks(self):
        """Head-GEMM block sizes when the user gave none: the per-group
        decode batch is tiny (max_batch / M rows), so the wrapper's
        MXU-aligned bb=128 default would pad it ~64x with zero rows every
        step — clamp bb to the smallest power of two covering the group."""
        if self.scfg.blocks is not None or self.scfg.ft_mode != "entangle":
            return self.scfg.blocks
        gsz = self.scfg.max_batch // self.scfg.ft_M
        bb = 8
        while bb < min(gsz, 128):
            bb *= 2
        return {"bb": bb}

    # -- jitted programs ------------------------------------------------------

    def _pad_sids(self, taken: list) -> tuple:
        """(sids [Bp], valid [Bp]) for ``_scatter_rows``: the ``taken``
        slots first, padded to Bp rows with DISTINCT unused slots (pad rows
        are write-back no-ops, and distinctness keeps the scatter
        order-independent). Single source of the invariant for admission
        scatter and recycle flush; requires len(taken) <= Bp <= max_batch
        (enforced at init)."""
        spare = [s for s in range(self.scfg.max_batch) if s not in taken]
        sids = np.asarray(taken + spare[: self.Bp - len(taken)], np.int32)
        valid = np.arange(self.Bp) < len(taken)
        return jnp.asarray(sids), jnp.asarray(valid)

    def _scatter_rows_impl(self, cache, pcache, sids, valid, zero):
        """Scatter ALL rows of an admission-batch (or zeros-template)
        pytree into the batched cache in ONE call: row j lands in slot
        ``sids[j]``; rows with ``valid[j] == False`` write the slot's own
        gathered content back (a no-op), and rows with ``zero[j] == True``
        write ZEROS instead of their pcache content — recycled-slot
        zeroing rides in the SAME scatter as the admission insert, so one
        trace (and one dispatch) serves any mix of admission rows, recycle
        rows and padding. ``sids``/``valid``/``zero`` are traced; the
        caller guarantees sids are DISTINCT slots."""
        def ins(big, small):
            cur = jnp.take(big, sids, axis=1)
            v = valid.reshape((1, -1) + (1,) * (big.ndim - 2))
            z = zero.reshape((1, -1) + (1,) * (big.ndim - 2))
            src = jnp.where(z, jnp.zeros_like(small), small)
            return big.at[:, sids].set(jnp.where(v, src, cur))
        return jax.tree.map(ins, cache, pcache)

    def _scatter(self, pcache, sids, valid, zero):
        """Host wrapper over the jitted batched scatter: one call = one
        dispatch (``scatter_calls`` is the trace-count evidence that
        recycling and insert really share a scatter per step)."""
        self.cache = self._scatter_rows(
            self.cache, pcache, sids, jnp.asarray(valid), jnp.asarray(zero))
        self.scatter_calls += 1

    def _model_ft(self, failed_group: Optional[int]):
        """The FT context threaded INTO the model forward pass, or None
        when no in-model site is protected (ft off, or scope == 'head'
        where protection lives entirely in the engine's head projection)."""
        if self.scfg.ft_mode != "entangle" or self.scfg.ft_scope == "head":
            return None
        return self.ftx.with_failed(failed_group)

    def _prefill_chunk_impl(self, params, tokens, cache, lengths, h_last,
                            pos0: int = 0,
                            failed_group: Optional[int] = None):
        """ONE chunk of the batched admission prefill: tokens [Bp, C] at
        absolute positions pos0..pos0+C-1, per-row true ``lengths``.
        Captures each row's last-prompt hidden state in ``h_last`` as soon
        as the chunk containing position lengths-1 is processed. With an
        ft_scope beyond 'head', the chunk's QKV/MLP/router GEMMs run
        entangled and ``failed_group`` is rolled forward inside them."""
        ctx = (sharding.axis_rules(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            h, new_cache = self.model.prefill_chunk(
                params, tokens, self.cfg, cache, pos0=pos0, lengths=lengths,
                ft=self._model_ft(failed_group))
            C = tokens.shape[1]
            idx = lengths - 1 - pos0
            in_chunk = (idx >= 0) & (idx < C)
            h_at = jnp.take_along_axis(
                h, jnp.clip(idx, 0, C - 1)[:, None, None], axis=1)[:, 0]
            h_last = jnp.where(in_chunk[:, None], h_at, h_last)
            return h_last, new_cache

    def _prefill_packed_impl(self, params, pack_cache, hlast, tok, sids,
                             pos0r, lengths, valid,
                             failed_group: Optional[int] = None):
        """ONE token-packed prefill step: ``tok`` [Rp, Cp] holds each
        packed row's next chunk of TRUE prompt tokens, row r staged in
        slot ``sids[r]`` at absolute offset ``pos0r[r]`` with true prompt
        length ``lengths[r]``. All metadata is TRACED — one compiled shape
        serves every packing mix. Gathers the rows' staging state (slot
        axis 1), zeroes FRESH rows (pos0 == 0) so a recycled staging row
        can never leak a predecessor's state into a new prompt, runs the
        model's token-packed prefill, captures each row's last-prompt
        hidden state, and scatters ``valid`` rows back (pad rows write
        their own gathered content back — a no-op; sids are DISTINCT, so
        the scatter is order-free)."""
        ctx = (sharding.axis_rules(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            fresh = pos0r == 0
            def take(a):
                rows = jnp.take(a, sids, axis=1)
                f = fresh.reshape((1, -1) + (1,) * (rows.ndim - 2))
                return jnp.where(f, jnp.zeros_like(rows), rows)
            rows = jax.tree.map(take, pack_cache)
            h, new_rows = self.model.prefill_packed(
                params, tok, self.cfg, rows, pos0=pos0r, lengths=lengths,
                ft=self._model_ft(failed_group))
            Cp = tok.shape[1]
            idx = lengths - 1 - pos0r
            in_chunk = (idx >= 0) & (idx < Cp)
            h_at = jnp.take_along_axis(
                h, jnp.clip(idx, 0, Cp - 1)[:, None, None], axis=1)[:, 0]
            hrow = jnp.where(in_chunk[:, None], h_at,
                             jnp.take(hlast, sids, axis=0))
            def put(big, small):
                cur = jnp.take(big, sids, axis=1)
                v = valid.reshape((1, -1) + (1,) * (big.ndim - 2))
                return big.at[:, sids].set(jnp.where(v, small, cur))
            pack_cache = jax.tree.map(put, pack_cache, new_rows)
            hlast = hlast.at[sids].set(
                jnp.where(valid[:, None], hrow,
                          jnp.take(hlast, sids, axis=0)))
            return pack_cache, hlast

    def _gather_rows_impl(self, pack_cache, hlast, sids):
        """Landing gather: pull a finished admission batch's staging rows
        (slot axis 1) and last-prompt hidden states into [Bp]-row order so
        the legacy landing tail (``_prefill_head`` + ``_scatter``) runs
        unchanged on packed batches."""
        rows = jax.tree.map(lambda a: jnp.take(a, sids, axis=1), pack_cache)
        return rows, jnp.take(hlast, sids, axis=0)

    def _head_logits(self, params, h, mask, head, failed_group, ft_fn):
        """Shared head epilogue of decode steps and admission batches:
        rows where ``mask`` is False are zeroed so their garbage logits
        are deterministic (activation quantization is PER ROW, so masked
        rows could not move a live row's grid either way); with ft on,
        ``ft_fn`` (ft_logits_decode / ft_logits_prefill) runs the fused
        entangled int8 GEMM with the startup plan, scaled back to
        head_project's muP readout temperature (argmax-neutral; keeps ft
        and plain logits on one scale)."""
        if self.scfg.ft_mode != "entangle":
            return self.model.head_project(params, h, self.cfg)
        head_q, w_scale = head
        hf = jnp.where(mask[:, None], h.astype(jnp.float32), 0.0)
        logits = ft_fn(
            hf, head_q, w_scale, plan=self.plan, failed_group=failed_group,
            use_pallas=self.scfg.use_pallas, blocks=self._head_blocks)
        return logits * readout_scale(self.cfg)

    def _prefill_head_impl(self, params, h_last, valid, head,
                           failed_group: Optional[int] = None):
        """First generated token of every admission row: project the
        gathered last-prompt hidden states. With ft on this runs the SAME
        fused entangled int8 GEMM (and plan) as the decode head, so a
        fail-stop during admission rolls forward in-kernel."""
        ctx = (sharding.axis_rules(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            logits = self._head_logits(params, h_last, valid, head,
                                       failed_group, ft_logits_prefill)
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _decode_impl(self, params, cache, last_tok, pos, active, head,
                     failed_group: Optional[int] = None):
        """ONE decode step for the whole slot pool. ``pos`` is the per-slot
        position vector; ``active`` masks which rows carry live requests
        (inactive rows compute garbage that admission later overwrites).
        ``head`` is (head_q, w_scale) — passed as a jit argument, not a
        closure constant, so every failed_group retrace shares ONE device
        buffer for the [D, V] quantized head (None when ft is off)."""
        ctx = (sharding.axis_rules(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            tok = last_tok[:, None]
            h, new_cache = self.model.decode_hidden(
                params, tok, cache, pos, self.cfg,
                ft=self._model_ft(failed_group))
            logits = self._head_logits(params, h, active, head,
                                       failed_group, ft_logits_decode)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, new_cache

    # -- admission pipeline ---------------------------------------------------

    def _census_bump(self, kind: str, sig: tuple):
        self.census[kind][sig] = self.census[kind].get(sig, 0) + 1

    def _plan_admission(self) -> bool:
        """Form the next admission batch: order the wait queue
        earliest-deadline-first (FIFO among deadline-less requests — the
        legacy order when nobody sets deadlines), pick the most urgent
        request's bucket, then batch every same-bucket queued request (EDF
        within the bucket) up to the free-slot / admission-row budget.

        With ``refill`` on this runs while other batches are still
        mid-chunk — freed slots re-enter the live prefill stream
        immediately; boundary mode admits one batch at a time (legacy).
        Planned rows RESERVE their slots so concurrent batches never claim
        the same row. Returns True if a batch was formed."""
        if not self.queue:
            return False
        if self._inflight and not self.scfg.refill:
            return False  # boundary mode: wait for the in-flight batch
        free = [i for i, s in enumerate(self.slots)
                if s is None and i not in self._reserved]
        if not free:
            return False
        ordered = self.sched.order_queue(self.queue)
        b0 = self._bucket_for(ordered[0])
        # refill-time plan reuse: the batch replays a census'd [Bp, bucket]
        # chunk program — a bucket outside the startup census would retrace
        assert b0 in self.buckets
        budget = min(len(free), self.Bp)
        take, rest = [], []
        for req in ordered:
            if len(take) < budget and self._bucket_for(req) == b0:
                take.append(req)
            else:
                rest.append(req)
        self.queue = rest
        if self._inflight:
            # a MID-FLIGHT refill: a new batch enters the live prefill
            # chunk stream while earlier batches are still mid-chunk —
            # exactly what boundary mode forbids (its engines report 0)
            self.metrics["refill_admissions"] += 1
        tokens = np.zeros((self.Bp, b0), np.int32)
        lengths = np.zeros(self.Bp, np.int32)
        for j, req in enumerate(take):
            tokens[j, : len(req.prompt)] = req.prompt
            lengths[j] = len(req.prompt)
            req.status = "prefill"
        slots = free[: len(take)]
        self._reserved.update(slots)
        self._inflight.append({
            "reqs": list(zip(slots, take)),
            "tokens": jnp.asarray(tokens),
            "lengths": jnp.asarray(lengths),
            "cache": self._fresh_prefill,
            "h_last": jnp.zeros((self.Bp, self.cfg.d_model), ACT_DTYPE),
            "pos0": 0,
            "bucket": b0,
            # host-side per-row state for token packing (pack_rows /
            # _advance_packed): true lengths, each row's prefill offset,
            # and the raw tokens to slice packed chunks from
            "tokens_np": tokens,
            "lengths_np": lengths,
            "rowpos": np.zeros(self.Bp, np.int32),
        })
        return True

    def _advance_prefill(self, p: dict, failed_group: Optional[int]):
        """Run ONE chunk of admission batch ``p``; on the last chunk,
        project first tokens and scatter the batch's cache rows — plus any
        deferred recycle-zero rows that fit the spare capacity — into the
        slot pool in ONE batched scatter."""
        Tb = p["bucket"]
        C = self.scfg.prefill_chunk or Tb
        pos0 = p["pos0"]
        sz = min(C, Tb - pos0)
        chunk_fn = self._prefill_chunk if pos0 == 0 else \
            self._prefill_chunk_cont
        # fail-stop injection reaches the chunk's protected GEMMs only
        # when an in-model scope is on; at scope 'head' the single healthy
        # chunk program serves every failed_group (head injection happens
        # in _prefill_head)
        fg = (failed_group if self._model_ft(failed_group) is not None
              else None)
        p["h_last"], p["cache"] = chunk_fn(
            self.ft_params, p["tokens"][:, pos0 : pos0 + sz], p["cache"],
            p["lengths"], p["h_last"], pos0=pos0, failed_group=fg)
        self.prefill_calls += 1
        p["pos0"] = pos0 + sz
        if p["pos0"] < Tb:
            return
        # census records BUCKET shapes (admission rows, padded length) —
        # the traced call signature — never raw prompt lengths
        self._census_bump("prefill", (self.Bp, Tb))
        self._land(p, failed_group)

    def _land(self, p: dict, failed_group: Optional[int]):
        """Land a COMPLETE admission batch (``p["cache"]`` / ``p["h_last"]``
        hold [Bp]-row final state — from the last legacy chunk or gathered
        out of the packed staging cache): project first tokens and scatter
        the batch's cache rows — plus any deferred recycle-zero rows that
        fit the spare capacity — into the slot pool in ONE batched scatter.
        Rows whose request was cancelled mid-prefill are masked invalid
        (they computed garbage under static shapes but never land)."""
        valid = [req is not None for _, req in p["reqs"]]
        vfull = np.zeros(self.Bp, bool)
        vfull[: len(valid)] = valid
        head = (None if self.scfg.ft_mode != "entangle"
                else (self.head_q, self.w_scale))
        first = np.asarray(self._prefill_head(
            self.ft_params, p["h_last"], jnp.asarray(vfull), head,
            failed_group=failed_group))
        sids = [i for i, _ in p["reqs"]]
        vrows, zero = list(valid), [False] * len(sids)
        merge = [i for i in self._dirty
                 if self.slots[i] is None and i not in self._reserved
                 and i not in sids][: self.Bp - len(sids)]
        for i in merge:
            sids.append(i)
            vrows.append(True)
            zero.append(True)
            self._dirty.remove(i)
        self.metrics["merged_zero_rows"] += len(merge)
        spare = [s for s in range(self.scfg.max_batch) if s not in sids]
        sids = np.asarray(sids + spare[: self.Bp - len(sids)], np.int32)
        vmask = np.zeros(self.Bp, bool)
        vmask[: len(vrows)] = vrows
        zmask = np.zeros(self.Bp, bool)
        zmask[: len(zero)] = zero
        self._scatter(p["cache"], jnp.asarray(sids), vmask, zmask)
        now = self._clock()
        for j, (i, req) in enumerate(p["reqs"]):
            self._reserved.discard(i)
            if req is None:  # cancelled mid-prefill: row never lands
                continue
            self.slots[i] = {"req": req, "toks": [int(first[j])]}
            self.pos[i] = len(req.prompt)
            self.last_tok[i] = first[j]
            req.status = "decoding"
            self._emit(req, int(first[j]), now)
            if req.max_new <= 1 or (req.eos_token is not None
                                    and int(first[j]) == req.eos_token):
                self._finish(i)
        self.metrics["landings"] += 1
        self._inflight.remove(p)

    # -- token-packed admission ----------------------------------------------

    def _advance_packed(self, failed_group: Optional[int]) -> bool:
        """Run ONE token-packed prefill step: draw up to ``Rp`` rows from
        ALL in-flight admission batches (EDF + shortest-remaining-prefill,
        token-granular — :meth:`ChunkScheduler.pack_rows`), build the
        fixed-shape [Rp, Cp] token block with per-row (slot, pos0, length)
        metadata, advance every packed row by one chunk of its TRUE prompt
        in a single program, then land every batch whose live rows have
        all finished (cancelled rows pack nothing and all-cancelled
        batches drain without compute). Returns True if any row packed."""
        rows = self.sched.pack_rows(self._inflight, self.Rp)
        if rows:
            tok = np.zeros((self.Rp, self.Cp), np.int32)
            sids = np.zeros(self.Rp, np.int32)
            pos0r = np.zeros(self.Rp, np.int32)
            lens = np.zeros(self.Rp, np.int32)
            valid = np.zeros(self.Rp, bool)
            used = []
            true_toks = 0
            for r, (p, i) in enumerate(rows):
                off = int(p["rowpos"][i])
                n = min(self.Cp, int(p["lengths_np"][i]) - off)
                tok[r, :n] = p["tokens_np"][i, off : off + n]
                sids[r] = p["reqs"][i][0]
                pos0r[r] = off
                lens[r] = p["lengths_np"][i]
                valid[r] = True
                used.append(int(sids[r]))
                true_toks += n
            # pad rows stage in DISTINCT spare slots (their content is
            # gathered, run, and written back unchanged — valid is False)
            spare = [s for s in range(self.scfg.max_batch)
                     if s not in used]
            for r in range(len(rows), self.Rp):
                sids[r] = spare.pop()
            fg = (failed_group if self._model_ft(failed_group) is not None
                  else None)
            self._pack_cache, self._pack_hlast = self._prefill_packed(
                self.ft_params, self._pack_cache, self._pack_hlast,
                jnp.asarray(tok), jnp.asarray(sids), jnp.asarray(pos0r),
                jnp.asarray(lens), jnp.asarray(valid), failed_group=fg)
            self.prefill_calls += 1
            self.metrics["packed_calls"] += 1
            self.metrics["packed_tokens"] += true_toks
            self.metrics["packed_batches_peak"] = max(
                self.metrics["packed_batches_peak"],
                len({id(p) for p, _ in rows}))
            # ONE compiled shape whatever the packing mix — the census
            # records the [Rp, Cp] program signature, never the mix
            self._census_bump("prefill", (self.Rp, self.Cp))
            for p, i in rows:
                p["rowpos"][i] = min(int(p["rowpos"][i]) + self.Cp,
                                     int(p["lengths_np"][i]))
        for p in list(self._inflight):
            live = [i for i, (_, r) in enumerate(p["reqs"])
                    if r is not None]
            if all(int(p["rowpos"][i]) >= int(p["lengths_np"][i])
                   for i in live):
                self._land_packed(p, failed_group)
        return bool(rows)

    def _land_packed(self, p: dict, failed_group: Optional[int]):
        """Gather a finished packed batch's staging rows into [Bp]-row
        order (original admission row order j — so the landing head's
        row -> group mapping ``j % M`` matches legacy chunking bit-for-
        bit) and run the shared landing tail."""
        sids_l = [i for i, _ in p["reqs"]]
        spare = [s for s in range(self.scfg.max_batch) if s not in sids_l]
        gsids = np.asarray(sids_l + spare[: self.Bp - len(sids_l)],
                           np.int32)
        p["cache"], p["h_last"] = self._gather_rows(
            self._pack_cache, self._pack_hlast, jnp.asarray(gsids))
        self._land(p, failed_group)

    def _emit(self, req: Request, tok: int, now: float):
        """Push a generated token into the request's streaming ring and
        stamp the latency bookkeeping (TTFT, per-token times)."""
        if req.t_first is None:
            req.t_first = now
        req.tok_times.append(now)
        ring = self._rings.get(id(req))
        if ring is not None:
            ring.push(tok)

    def _finish(self, i: int):
        s = self.slots[i]
        req = s["req"]
        req.out = np.asarray(s["toks"][: req.max_new], np.int32)
        req.status = "done"
        req.t_done = self._clock()
        self._rings.pop(id(req), None)  # handle keeps its own ring ref
        self.done.append(req)
        self._recycle(i)

    def cancel(self, req: Request):
        """Abandon a request in whatever state it is in: queued requests
        leave the queue; mid-prefill rows are voided (their chunk keeps
        computing under static shapes but never claims a slot, and the
        reserved slot frees immediately); decoding slots finalize their
        partial output and recycle. Finished requests are a no-op."""
        if req.status in ("done", "cancelled", "shed"):
            return
        if req.status == "queued":
            self.queue = [r for r in self.queue if r is not req]
        elif req.status == "prefill":
            for p in self._inflight:
                for j, (slot, r) in enumerate(p["reqs"]):
                    if r is req:
                        p["reqs"][j] = (slot, None)
                        self._reserved.discard(slot)
        else:  # decoding
            for i, s in enumerate(self.slots):
                if s is not None and s["req"] is req:
                    req.out = np.asarray(s["toks"], np.int32)
                    self._recycle(i)
        req.status = "cancelled"
        if req.out is None:
            req.out = np.zeros(0, np.int32)
        req.t_done = self._clock()
        self._rings.pop(id(req), None)
        self.metrics["cancelled"] += 1

    def _recycle(self, i: int):
        """Explicit slot recycling: mark the slot free and queue its cache
        row for zeroing, so no later tenant (or FT quantization scan) can
        see the old request's state.

        Admission would overwrite the row anyway, so this buys the
        invariant "a free slot's row is zeroed again before the next
        decode" — the zeroing itself is DEFERRED: it rides in the next
        landing scatter's spare capacity (``_advance_prefill``) or, when
        no landing absorbs it, one batched ``_flush_recycled`` scatter
        before decode — never one jitted insert per finished request."""
        self.slots[i] = None
        self.pos[i] = 0
        self.last_tok[i] = 0
        self._dirty.append(i)

    def _flush_recycled(self):
        """Zero freed slot rows that no landing scatter absorbed, one
        batched scatter per Bp slots. Re-occupied slots are skipped (their
        row was fully overwritten at landing); slots reserved by an
        in-flight batch stay queued for later (landing overwrites them —
        unless the row gets cancelled, in which case a later flush zeroes
        them)."""
        keep, flush = [], []
        for i in sorted(set(self._dirty)):
            if self.slots[i] is not None:
                continue
            (keep if i in self._reserved else flush).append(i)
        self._dirty = keep
        while flush:
            batch, flush = flush[: self.Bp], flush[self.Bp :]
            sids, vmask = self._pad_sids(batch)
            self._scatter(self._fresh_prefill, sids,
                          np.asarray(vmask), np.asarray(vmask))

    def step(self, failed_group: Optional[int] = None) -> int:
        """One engine step: advance the bucketed admission pipeline, then
        ONE batched jitted decode call for all active slots. Returns the
        number of active slots.

        Unchunked (``prefill_chunk=0``): every bucket batch completes in a
        single call, and the step keeps admitting further batches while
        free slots and queued requests remain. Chunked: at most
        ``max_prefill_per_step`` prefill chunks (default 1, EDF-ordered
        across the in-flight batches) run per step before the decode call,
        so a long prompt batch being admitted never stalls the decode
        latency of active slots — and with ``refill`` on, slots freed by
        finishing requests are planned straight back into the live chunk
        stream instead of waiting for the wave to drain.

        ``failed_group`` injects a fail-stop into that entangled group's
        head-GEMM compute for this step — decode and admission projections
        alike; the kernel rolls it forward, so outputs are unchanged."""
        if failed_group is not None:
            if self.scfg.ft_mode != "entangle":
                raise ValueError("failed_group requires ft_mode='entangle'")
            if not 0 <= failed_group < self.scfg.ft_M:
                # the kernel indexes streams mod M; wrapping silently would
                # make an injection drill report a group it never failed
                raise ValueError(
                    f"failed_group={failed_group} out of range for "
                    f"ft_M={self.scfg.ft_M}")
        # shed lapsed deadlines BEFORE spending any prefill compute on
        # them — they would miss their SLA anyway, and the refunded chunk
        # budget goes to requests that can still make it
        if any(r.deadline_ms is not None for r in self.queue):
            kept, shed = self.sched.shed_expired(self.queue)
            self.queue = kept
            for req in shed:
                req.status = "shed"
                req.out = np.zeros(0, np.int32)
                req.t_done = self._clock()
                self._rings.pop(id(req), None)
                self.metrics["shed"] += 1
        # admission: plan (EDF over the wait queue; with refill, freed
        # slots re-enter the stream mid-flight) and advance up to the
        # chunk budget. Unchunked admission completes a batch per call, so
        # the budget is infinite and the loop drains queue + free slots
        # within the step exactly like boundary admission always did.
        if self.Rp:
            # token-packed admission: plan every formable batch FIRST so
            # mixed-bucket admissions co-pack into the same [Rp, Cp]
            # program, then run up to max_prefill_per_step packed steps
            for _ in range(self.scfg.max_prefill_per_step):
                while self._plan_admission():
                    pass
                if not self._advance_packed(failed_group):
                    break
        else:
            budget = (self.scfg.max_prefill_per_step
                      if self.scfg.prefill_chunk else float("inf"))
            while budget > 0:
                self._plan_admission()
                p = self.sched.pick_batch(self._inflight)
                if p is None:
                    break
                self._advance_prefill(p, failed_group)
                budget -= 1
        # zero any freed rows no landing scatter absorbed: decode below
        # sees exactly the state boundary admission would have produced
        self._flush_recycled()
        active_idx = [i for i, s in enumerate(self.slots) if s is not None]
        if active_idx:
            B = self.scfg.max_batch
            active = np.zeros(B, bool)
            active[active_idx] = True
            head = (None if self.scfg.ft_mode != "entangle"
                    else (self.head_q, self.w_scale))
            nxt, self.cache = self._decode(
                self.ft_params, self.cache, jnp.asarray(self.last_tok),
                jnp.asarray(self.pos), jnp.asarray(active), head,
                failed_group=failed_group)
            self.decode_calls += 1
            self._census_bump("decode", (len(active_idx), B))
            nxt = np.asarray(nxt)
            now = self._clock()
            for i in active_idx:
                s = self.slots[i]
                req = s["req"]
                self.pos[i] += 1
                tok = int(nxt[i])
                s["toks"].append(tok)
                self.last_tok[i] = nxt[i]
                self._emit(req, tok, now)
                if (len(s["toks"]) >= req.max_new
                        or (req.eos_token is not None
                            and tok == req.eos_token)):
                    self._finish(i)
        return sum(s is not None for s in self.slots)

    def idle(self) -> bool:
        """True when the engine has nothing to serve: empty wait queue, no
        admission batch mid-chunk, every slot free. Open-loop drivers poll
        this to decide between stepping and waiting for the next arrival."""
        return (not self.queue and not self._inflight
                and all(s is None for s in self.slots))

    def run_to_completion(self, max_steps: int = 1000,
                          failed_group: Optional[int] = None) -> list[Request]:
        """Drain the queue. ``failed_group`` injects the fail-stop on EVERY
        decode step and admission projection — the strongest roll-forward
        drill."""
        steps = 0
        while not self.idle() and steps < max_steps:
            self.step(failed_group=failed_group)
            steps += 1
        return self.done

    # -- startup autotune warmup ---------------------------------------------

    def warm_autotune(self) -> dict:
        """Warm the kernel autotune cache for the engine's protected-GEMM
        shape census — the head's decode AND prefill-admission shapes plus,
        with an ``ft_scope`` beyond ``head``, EVERY in-model protected site
        at every decode/chunk call shape (the ROADMAP contract). Sweeps run
        HERE, eagerly; the in-jit ``blocks='auto'`` resolution then only
        ever cache-hits, whether it fires inside the traced decode step,
        a traced prefill chunk or a traced head projection. No-op unless
        the entangled head is on and ``blocks == 'auto'``."""
        if self.scfg.ft_mode != "entangle" or self.scfg.blocks != "auto":
            return {}
        M, B = self.plan.M, self.scfg.max_batch
        D, V = self._head_dims  # true dims; self.head_q may be packed
        packed = self.scfg.ft_packed
        # prefill admission batches are padded to a multiple of M
        # (ft_logits_prefill), so the per-group row count is ceil(Bp / M)
        shapes = {(M, B // M, D, V), (M, -(-self.Bp // M), D, V)}
        won = {}
        for shape in sorted(shapes):
            won[shape] = kops.warm_entangled_matmul(
                *shape, self.plan, fuse_epilogue=True, packed=packed)
            self.census.setdefault("head_gemm", {})[shape] = won[shape]
        for site, shape in sorted(self.protected_census):
            # 5-tuple shapes are grouped (MoE per-expert) sites
            warm = (kops.warm_entangled_matmul_grouped if len(shape) == 5
                    else kops.warm_entangled_matmul)
            w = warm(*shape, self.plan, fuse_epilogue=True, packed=packed)
            self.census.setdefault("protected", {})[(site, shape)] = w
            won[(site, shape)] = w
        return won

    def _all_chunk_widths(self) -> frozenset:
        """Every prefill-chunk width any admission can run, derived from
        the bucket set and chunk size alone. Mid-flight refill replays
        these SAME widths — a refilled batch is just another [Bp, bucket]
        program — which is why refill can never retrace or miss a compiled
        plan (``CompiledPlans.misses`` stays 0; tested)."""
        widths = set()
        for Tb in self.buckets:
            step = self.scfg.prefill_chunk or Tb
            pos0 = 0
            while pos0 < Tb:
                sz = min(step, Tb - pos0)
                widths.add(sz)
                pos0 += sz
        return frozenset(widths)

    def _protected_shape_census(self) -> dict:
        """{(site, (M, Bg, K, N)): blocks} for every in-model protected
        GEMM the engine can trace, enumerated by abstract-evaluating the
        decode step and one prefill chunk per distinct chunk width with a
        census-only :class:`repro.ft.FTContext` — every PlanEntry is
        constructed HERE, at startup, in the engine's own registry; no
        kernel runs, nothing compiles. Empty at ft_scope='head'."""
        if self.scfg.ft_mode != "entangle" or self.scfg.ft_scope == "head":
            return {}
        ctx = dataclasses.replace(self.ftx, census_only=True)
        B = self.scfg.max_batch
        jax.eval_shape(
            lambda p, c: self.model.decode_hidden(
                p, jnp.zeros((B, 1), jnp.int32), c,
                jnp.zeros((B,), jnp.int32), self.cfg, ft=ctx),
            self.params, self.cache)
        if self.Rp:
            # token-packed mode runs exactly ONE prefill program shape —
            # [Rp, Cp] tokens over Rp gathered staging rows — for every
            # packing mix, so the census holds one prefill entry set and
            # CompiledPlans.misses == 0 is checkable for any traffic
            jax.eval_shape(
                lambda p, c: self.model.prefill_packed(
                    p, jnp.zeros((self.Rp, self.Cp), jnp.int32), self.cfg,
                    c, pos0=jnp.zeros((self.Rp,), jnp.int32),
                    lengths=jnp.zeros((self.Rp,), jnp.int32), ft=ctx),
                self.params,
                self.model.init_cache(self.cfg, self.Rp, self.scfg.max_seq))
        else:
            for C in sorted(self._all_chunk_widths()):
                jax.eval_shape(
                    lambda p, c, _C=C: self.model.prefill_chunk(
                        p, jnp.zeros((self.Bp, _C), jnp.int32), self.cfg, c,
                        pos0=0, lengths=jnp.zeros((self.Bp,), jnp.int32),
                        ft=ctx),
                    self.params, self._fresh_prefill)
        return self.registry.census()
