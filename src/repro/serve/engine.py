"""Serving engine: slot-batched prefill/decode with FT-protected logits path.

Continuous-batching-lite: a fixed pool of B slots; new requests prefill into
free slots, active slots decode one token per engine step (prefill and decode
are separate jitted programs, as in production TPU serving).

Fault tolerance (the paper's technique in the serving path): with
``ft_mode='entangle'`` the final (int8-quantized) logits projection runs as
the fused entangled GEMM over M request groups — a fail-stop/straggler in
one group's compute is rolled forward from the other M-1 groups' entangled
outputs, so no request in the batch observes the failure.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.api import get_model


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4  # slot count; must be divisible by ft_M if entangling
    max_seq: int = 256
    ft_mode: str = "none"  # none | entangle
    ft_M: int = 4
    ft_w: int = 32
    greedy: bool = True


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [T] int32
    max_new: int = 16
    out: Optional[np.ndarray] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, scfg: ServeConfig, params):
        self.cfg, self.scfg, self.params = cfg, scfg, params
        self.model = get_model(cfg)
        B, S = scfg.max_batch, scfg.max_seq
        self.cache = self.model.init_cache(cfg, 1, S)  # per-slot caches
        self.slots: list[Optional[dict]] = [None] * B
        self.queue: list[Request] = []
        self.done: list[Request] = []
        self._prefill = jax.jit(
            lambda p, b, c: self.model.prefill(p, b, self.cfg, c))
        self._decode = jax.jit(
            lambda p, t, c, pos: self.model.decode_step(p, t, c, pos, self.cfg))
        self._slot_cache = [self.model.init_cache(cfg, 1, S) for _ in range(B)]

    def submit(self, req: Request):
        self.queue.append(req)

    def _sample(self, logits: jax.Array) -> int:
        return int(jnp.argmax(logits, -1))

    def step(self, failed_group: Optional[int] = None) -> int:
        """One engine step: admit + prefill new requests, decode active.
        Returns number of active slots. ``failed_group`` injects a fail-stop
        into the entangled logits path of the decode batch."""
        # admit
        for i in range(len(self.slots)):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                tokens = jnp.asarray(req.prompt[None, :])
                logits, cache = self._prefill(
                    self.params, {"tokens": tokens}, self._slot_cache[i])
                tok = self._sample(logits[0])
                self.slots[i] = {
                    "req": req, "cache": cache, "pos": len(req.prompt),
                    "toks": [tok],
                }
        # decode active slots
        active = [i for i, s in enumerate(self.slots) if s is not None]
        for i in active:
            s = self.slots[i]
            tok_in = jnp.asarray([[s["toks"][-1]]], dtype=jnp.int32)
            logits, s["cache"] = self._decode(
                self.params, tok_in, s["cache"], s["pos"])
            if self.scfg.ft_mode == "entangle":
                logits = self._ft_logits_check(logits, i, failed_group)
            s["pos"] += 1
            s["toks"].append(self._sample(logits[0]))
            req = s["req"]
            if len(s["toks"]) > req.max_new:
                req.out = np.asarray(s["toks"][: req.max_new], np.int32)
                self.done.append(req)
                self.slots[i] = None
        return sum(s is not None for s in self.slots)

    # -- FT path: entangled int8 logits GEMM across M request groups --------
    def _ft_logits_check(self, logits, slot, failed_group):
        # per-slot engine: group index = slot % M; a failed group's logits
        # would be recovered from the entangled outputs of other groups.
        # The full batched path (with recovery) lives in serve/ft_logits.py
        # and examples/serve_lm.py; here we only tag the group.
        del slot, failed_group
        return logits

    def run_to_completion(self, max_steps: int = 1000) -> list[Request]:
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) and steps < max_steps:
            self.step()
            steps += 1
        return self.done
