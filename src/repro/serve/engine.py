"""Batched continuous-batching serving engine with the entangled logits
head on the real hot path.

One engine step issues ONE jitted decode call over the whole slot pool:

  * the KV/recurrent cache is slot-batched — a single pytree with batch
    dim ``max_batch``, every slot one row;
  * each slot decodes at its own position (the model decode contract takes
    an int32 position VECTOR [B]); admission and eviction only flip values
    in the position/active arrays, never shapes, so the decode program
    compiles once and is never retraced as traffic churns;
  * admission prefills a request at batch 1 (retraced per prompt length,
    like any bucketed prefill), then scatters the fresh slot cache into the
    batched cache with a jitted dynamic-slice insert;
  * slot recycling is explicit: a finished slot's cache row is overwritten
    with zeros, so no tenant can observe a predecessor's KV or recurrent
    state.

Fault tolerance (the paper's technique in the serving path): with
``ft_mode='entangle'`` the final logits projection of EVERY decode step runs
as the fused entangled int8 GEMM over M request groups
(serve/ft_logits.ft_logits_decode), slots mapped round-robin to groups
(slot -> group = slot % M). ``step(failed_group=r)`` injects a fail-stop
into group r's compute; the in-kernel roll-forward recovers its logits from
the other M-1 groups' entangled accumulators, so decoded tokens are
bit-identical with and without the failure — no request observes it.

Autotune warmup contract: with ``blocks='auto'`` the engine sweeps the head
GEMM's block sizes at startup (``warm_autotune``) for its decode shape
census, so the in-jit ``blocks='auto'`` resolution is a pure cache hit —
sweeps must never run inside a traced decode step.

On hosts with more than one device the decode step traces under
``dist.sharding.serve_mesh()``, sharding the slot batch (and the head GEMM)
across devices.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.plan import make_plan
from repro.dist import sharding
from repro.kernels import ops as kops
from repro.models.api import get_model
from repro.models.transformer import readout_scale
from repro.serve.ft_logits import ft_logits_decode, quantize_head


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4  # slot count; must be divisible by ft_M if entangling
    max_seq: int = 256
    ft_mode: str = "none"  # none | entangle
    ft_M: int = 4
    ft_w: int = 32
    greedy: bool = True
    # head-GEMM block sizes: None | dict | "auto" (autotuned at startup)
    blocks: Optional[object] = None
    use_pallas: bool = True  # entangled head via Pallas (False: XLA einsum)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # [T] int32
    max_new: int = 16
    out: Optional[np.ndarray] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, scfg: ServeConfig, params):
        self.cfg, self.scfg, self.params = cfg, scfg, params
        if not scfg.greedy:
            raise NotImplementedError("only greedy decode is implemented")
        self.model = get_model(cfg)
        B, S = scfg.max_batch, scfg.max_seq
        # THE slot-batched cache: one pytree, slot i = batch row i
        self.cache = self.model.init_cache(cfg, B, S)
        # zero slot template: source for admission prefills and recycling
        self._fresh_slot = self.model.init_cache(cfg, 1, S)
        self.slots: list[Optional[dict]] = [None] * B
        self.queue: list[Request] = []
        self.done: list[Request] = []
        self.pos = np.zeros(B, np.int32)  # per-slot next decode position
        self.last_tok = np.zeros(B, np.int32)
        self.census: dict[str, dict] = {"prefill": {}, "decode": {}}
        self.decode_calls = 0  # jitted decode invocations (one per step)
        self.mesh = sharding.serve_mesh()

        if scfg.ft_mode == "entangle":
            if B % scfg.ft_M:
                raise ValueError(
                    f"max_batch={B} must be divisible by ft_M={scfg.ft_M}")
            # plan reuse: made ONCE, every decode step and autotune key
            # shares it (no per-step (l, k) re-planning)
            self.plan = make_plan(scfg.ft_M, scfg.ft_w)
            self.head_q, self.w_scale = quantize_head(
                self.model.head_weights(params, cfg))
        elif scfg.ft_mode != "none":
            raise ValueError(f"unknown ft_mode {scfg.ft_mode!r}")
        self._head_blocks = self._default_head_blocks()

        # donate the slot-batched cache through decode/insert so XLA aliases
        # it in place instead of copying the engine's largest buffer every
        # token (donation is a no-op warning on CPU, so gate it)
        donate = jax.default_backend() != "cpu"
        self._prefill = jax.jit(
            lambda p, b, c: self.model.prefill(p, b, self.cfg, c))
        self._insert = jax.jit(self._insert_impl,
                               donate_argnums=(0,) if donate else ())
        self._decode = jax.jit(self._decode_impl,
                               static_argnames=("failed_group",),
                               donate_argnums=(1,) if donate else ())
        if scfg.blocks == "auto":
            self.warm_autotune()

    def submit(self, req: Request):
        # loud capacity check: past max_seq the vector cache scatter would
        # silently DROP K/V writes (and the reference engine would clamp),
        # turning overflow into wrong tokens instead of an error
        need = len(req.prompt) + req.max_new
        if need > self.scfg.max_seq:
            raise ValueError(
                f"request rid={req.rid} needs {need} positions "
                f"(prompt {len(req.prompt)} + max_new {req.max_new}) "
                f"> max_seq={self.scfg.max_seq}")
        self.queue.append(req)

    def _default_head_blocks(self):
        """Head-GEMM block sizes when the user gave none: the per-group
        decode batch is tiny (max_batch / M rows), so the wrapper's
        MXU-aligned bb=128 default would pad it ~64x with zero rows every
        step — clamp bb to the smallest power of two covering the group."""
        if self.scfg.blocks is not None or self.scfg.ft_mode != "entangle":
            return self.scfg.blocks
        gsz = self.scfg.max_batch // self.scfg.ft_M
        bb = 8
        while bb < min(gsz, 128):
            bb *= 2
        return {"bb": bb}

    # -- jitted programs ------------------------------------------------------

    def _insert_impl(self, cache, slot_cache, i):
        """Scatter a batch-1 slot cache into batch row ``i`` of the batched
        cache. ``i`` is traced — admit/evict never retraces."""
        def ins(big, small):
            return jax.lax.dynamic_update_slice_in_dim(big, small, i, axis=1)
        return jax.tree.map(ins, cache, slot_cache)

    def _decode_impl(self, params, cache, last_tok, pos, active, head,
                     failed_group: Optional[int] = None):
        """ONE decode step for the whole slot pool. ``pos`` is the per-slot
        position vector; ``active`` masks which rows carry live requests
        (inactive rows compute garbage that admission later overwrites).
        ``head`` is (head_q, w_scale) — passed as a jit argument, not a
        closure constant, so every failed_group retrace shares ONE device
        buffer for the [D, V] quantized head (None when ft is off)."""
        ctx = (sharding.axis_rules(self.mesh) if self.mesh is not None
               else contextlib.nullcontext())
        with ctx:
            tok = last_tok[:, None]
            h, new_cache = self.model.decode_hidden(
                params, tok, cache, pos, self.cfg)
            if self.scfg.ft_mode == "entangle":
                head_q, w_scale = head
                # inactive rows are zeroed so their garbage cannot poison
                # the shared activation quantization scale
                hf = jnp.where(active[:, None], h.astype(jnp.float32), 0.0)
                logits = ft_logits_decode(
                    hf, head_q, w_scale, plan=self.plan,
                    failed_group=failed_group,
                    use_pallas=self.scfg.use_pallas,
                    blocks=self._head_blocks)
                # match head_project's muP readout temperature (argmax-
                # neutral; keeps ft and plain logits on one scale)
                logits = logits * readout_scale(self.cfg)
            else:
                logits = self.model.head_project(params, h, self.cfg)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, new_cache

    # -- engine steps ---------------------------------------------------------

    def _census_bump(self, kind: str, sig: tuple):
        self.census[kind][sig] = self.census[kind].get(sig, 0) + 1

    def _admit(self, i: int, req: Request):
        tokens = jnp.asarray(req.prompt[None, :].astype(np.int32))
        logits, slot_cache = self._prefill(
            self.params, {"tokens": tokens}, self._fresh_slot)
        self._census_bump("prefill", (1, int(tokens.shape[1])))
        tok = int(jnp.argmax(logits[0], -1))
        self.cache = self._insert(self.cache, slot_cache, jnp.int32(i))
        self.slots[i] = {"req": req, "toks": [tok]}
        self.pos[i] = len(req.prompt)
        self.last_tok[i] = tok
        if req.max_new <= 1:
            self._finish(i)

    def _finish(self, i: int):
        s = self.slots[i]
        req = s["req"]
        req.out = np.asarray(s["toks"][: req.max_new], np.int32)
        self.done.append(req)
        self._recycle(i)

    def _recycle(self, i: int):
        """Explicit slot recycling: zero the slot's cache row so no later
        tenant (or FT quantization scan) can see the old request's state.

        Admission would overwrite the row anyway, so this buys the
        invariant "a free slot holds zeros" at the cost of one jitted
        insert per FINISHED REQUEST (not per token) — kept for the loud
        state boundary, cheap relative to the request's decode steps."""
        self.slots[i] = None
        self.pos[i] = 0
        self.last_tok[i] = 0
        self.cache = self._insert(self.cache, self._fresh_slot, jnp.int32(i))

    def step(self, failed_group: Optional[int] = None) -> int:
        """One engine step: admit + prefill queued requests into free slots,
        then ONE batched jitted decode call for all active slots. Returns
        the number of active slots. ``failed_group`` injects a fail-stop
        into that entangled group's head-GEMM compute for this step; the
        kernel rolls it forward, so outputs are unchanged."""
        if failed_group is not None:
            if self.scfg.ft_mode != "entangle":
                raise ValueError("failed_group requires ft_mode='entangle'")
            if not 0 <= failed_group < self.scfg.ft_M:
                # the kernel indexes streams mod M; wrapping silently would
                # make an injection drill report a group it never failed
                raise ValueError(
                    f"failed_group={failed_group} out of range for "
                    f"ft_M={self.scfg.ft_M}")
        for i in range(len(self.slots)):
            if self.slots[i] is None and self.queue:
                self._admit(i, self.queue.pop(0))
        active_idx = [i for i, s in enumerate(self.slots) if s is not None]
        if active_idx:
            B = self.scfg.max_batch
            active = np.zeros(B, bool)
            active[active_idx] = True
            head = (None if self.scfg.ft_mode != "entangle"
                    else (self.head_q, self.w_scale))
            nxt, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(self.last_tok),
                jnp.asarray(self.pos), jnp.asarray(active), head,
                failed_group=failed_group)
            self.decode_calls += 1
            self._census_bump("decode", (len(active_idx), B))
            nxt = np.asarray(nxt)
            for i in active_idx:
                s = self.slots[i]
                self.pos[i] += 1
                s["toks"].append(int(nxt[i]))
                self.last_tok[i] = nxt[i]
                if len(s["toks"]) >= s["req"].max_new:
                    self._finish(i)
        return sum(s is not None for s in self.slots)

    def run_to_completion(self, max_steps: int = 1000,
                          failed_group: Optional[int] = None) -> list[Request]:
        """Drain the queue. ``failed_group`` injects the fail-stop on EVERY
        decode step — the strongest roll-forward drill."""
        steps = 0
        while (self.queue or any(s is not None for s in self.slots)) \
                and steps < max_steps:
            self.step(failed_group=failed_group)
            steps += 1
        return self.done

    # -- startup autotune warmup ---------------------------------------------

    def warm_autotune(self) -> dict:
        """Warm the kernel autotune cache for the engine's head-GEMM shape
        census (the ROADMAP contract). Sweeps run HERE, eagerly; the in-jit
        ``blocks='auto'`` resolution then only ever cache-hits. No-op unless
        the entangled head is on and ``blocks == 'auto'``."""
        if self.scfg.ft_mode != "entangle" or self.scfg.blocks != "auto":
            return {}
        M, B = self.plan.M, self.scfg.max_batch
        D, V = self.head_q.shape
        won = kops.warm_entangled_matmul(M, B // M, D, V, self.plan,
                                         fuse_epilogue=True)
        self.census.setdefault("head_gemm", {})[(M, B // M, D, V)] = won
        return won
