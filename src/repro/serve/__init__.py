"""Serving layer: the batched fault-tolerant engine and its entangled head.

  engine.ServeEngine     batched continuous-batching engine — one jitted
                         decode step for the whole slot pool, per-slot
                         positions, entangled int8 head GEMM on every decode
                         step when ft_mode='entangle' (slot -> group =
                         slot % M), startup autotune warmup
  reference.PerSlotEngine  the pre-batching per-slot baseline (A/B tests,
                         throughput benchmarks)
  ft_logits              the fused entangled int8 logits projection and its
                         batched-decode entry (ft_logits_decode)
"""
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.ft_logits import ft_logits, ft_logits_decode, quantize_head
from repro.serve.reference import PerSlotEngine

__all__ = [
    "PerSlotEngine",
    "Request",
    "ServeConfig",
    "ServeEngine",
    "ft_logits",
    "ft_logits_decode",
    "quantize_head",
]
