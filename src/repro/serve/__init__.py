"""Serving layer: the batched fault-tolerant engine and its entangled head.

  engine.ServeEngine     batched continuous-batching engine — one jitted
                         decode step for the whole slot pool, per-slot
                         positions, entangled int8 head GEMM on every decode
                         step when ft_mode='entangle' (slot -> group =
                         slot % M), protection widened to the in-model
                         QKV/MLP/router GEMMs via ServeConfig.ft_scope
                         (head | qkv | mlp | all; repro.ft subsystem),
                         startup autotune warmup over the full protected
                         shape census
  reference.PerSlotEngine  the pre-batching per-slot baseline (A/B tests,
                         throughput benchmarks)

The entangled int8 logits projection lives in :mod:`repro.ft.heads`
(ft_logits / ft_logits_decode / ft_logits_prefill / quantize_head) — the
only surface; this package re-exports those names directly (the old
``repro.serve.ft_logits`` deprecation shim is removed).

Prefill pipeline (admission hot path)
-------------------------------------
Admission runs as a bucketed, chunked batched prefill, never one batch-1
call per request:

  * **buckets** — queued prompts are padded to a small geometric set of
    length buckets (``ServeConfig.prefill_buckets``; default 8, 16, 32,
    ..., max_seq) and all same-bucket admits prefill in ONE batched
    [prefill_batch, bucket] call. The prefill program traces at most once
    per (bucket, chunk) shape; prompts longer than the largest bucket are
    rejected loudly at ``submit()``.
  * **chunks** — ``ServeConfig.prefill_chunk > 0`` splits each bucket into
    fixed-size chunks, ONE chunk per engine step, interleaved with the
    batched decode call (Sarathi-style), so admitting a long prompt batch
    never stalls decode latency of active slots.
  * **census -> warmup** — the engine records every admission call's
    BUCKET shape (rows, padded length) in ``census['prefill']`` and, with
    ``blocks='auto'``, sweeps the entangled head GEMM's block sizes at
    startup for decode and prefill-admission shapes alike
    (``ServeEngine.warm_autotune``), so ``blocks='auto'`` inside a traced
    prefill or decode step is always a pure cache hit.
  * **protection** — with ``ft_mode='entangle'`` the first token of every
    admitted request is projected through the same fused entangled int8
    kernel (and the same startup plan) as decode
    (:func:`repro.ft.heads.ft_logits_prefill`), so a fail-stop injected
    during admission rolls forward in-kernel, bit-identically.

Token-packed admission (``ServeConfig.token_budget``)
-----------------------------------------------------
``token_budget > 0`` replaces the per-batch ``[Bp, bucket]`` chunk
programs with ONE fixed-shape token-parallel program per step:

  * **packing** — each step draws up to
    ``token_budget // prefill_chunk`` rows (EDF + shortest-remaining-
    prefill, token-granular: :meth:`ChunkScheduler.pack_rows`) from ALL
    in-flight admission batches; each row is one request's next
    ``prefill_chunk`` tokens with (slot, pos0, length) metadata, and rows
    advance to the request's TRUE prompt length — bucket padding is never
    packed, which is where the density (and the FT-overhead-per-token)
    win comes from: the entangled codec cost is linear in the rows a
    program runs, so packing true tokens where bucket padding used to sit
    amortizes the same codec over more useful work.
  * **one shape** — the program is padded to the budget, so exactly ONE
    compiled ``[Rp, Cp]`` shape (and one census entry set) serves every
    packing mix — mixed buckets, ragged tails, single-token remainders,
    mid-pack cancels; ``CompiledPlans.misses`` stays 0 for any traffic.
  * **tuning token_budget** — larger budgets pack more co-resident rows
    per program (denser steps, fewer dispatches; bounded by
    rows <= max_batch since every row stages in a distinct slot); the
    budget must be a multiple of ``prefill_chunk``. A budget smaller than
    a bucket still works — rows just take more steps to finish.
  * **bit-identity** — slot -> group stays ``slot % M``, activation
    quantization is per row, and the entangled recovery is exact, so
    packed admission produces tokens bit-identical to per-batch chunking
    under fail-stop injection in every group (tested as a packed x arch x
    scope x failed-group matrix).

Steady-state pipeline (mid-flight refill + async frontend)
----------------------------------------------------------
Under sustained load the engine never quantizes admission to bucket-batch
boundaries:

  * **mid-flight refill** (``ServeConfig.refill``, default on) — the
    moment a slot finishes (``max_new``, EOS, cancel) it is recycled into
    the LIVE prefill chunk stream: new admission batches are planned over
    freed slots while earlier batches are still mid-chunk, so slots never
    idle waiting for a wave to drain. Time-to-first-token under an
    open-loop arrival trace drops accordingly (gated in
    ``benchmarks/serve_throughput.py`` / BENCH_serve.json).
  * **async API** (:mod:`repro.serve.scheduler`) — ``submit()`` returns a
    :class:`RequestHandle`: iterate it to stream tokens from a
    per-request ring buffer as decode steps land (the iterator drives
    ``engine.step()`` on demand), ``cancel()`` works queued, mid-prefill
    and decoding, ``Request.deadline_ms`` sets an SLA. Admission batches
    form and advance earliest-deadline-first (:class:`ChunkScheduler`;
    decode is never starved more than ``max_prefill_per_step`` chunks per
    step); ``max_queue`` bounds the wait queue with a typed
    :class:`AdmissionRejected` at saturation, and lapsed-deadline queued
    requests are shed loudly (:class:`DeadlineExceeded` on iteration).
    ``ServeEngine.metrics`` exposes queue depth, sheds, rejections,
    refills, landings and merged zero rows.
  * **why refill never changes FT group assignment** — slot -> group is
    POSITIONAL (``slot % M``) and plans are keyed by (site, shape): a
    refilled batch replays one of the census'd ``[Bp, bucket]`` chunk
    programs, so the same plans, block sizes and kernels serve it with no
    retrace (``CompiledPlans.misses`` stays 0). Activation quantization
    is per ROW (:mod:`repro.ft.quantize`), so WHICH requests are
    co-resident — i.e. WHEN a slot was refilled — cannot move any other
    request's integer grid: tokens and the entangled roll-forward are
    bit-identical under refill and boundary admission (tested as a
    refill x fail-stop matrix across dense/ssm/hybrid x scopes x groups).

Multi-replica fleet (router + replica pool + fail-stop migration)
-----------------------------------------------------------------
:mod:`repro.serve.fleet` lifts the paper's fail-stop story one level up:
lose a whole REPLICA (machine), keep every request — the fleet analogue
of the in-kernel stream roll-forward.

  * **router / replica split** (:mod:`repro.serve.router`,
    :mod:`repro.serve.transport`) — a front-end :class:`Router` owns ALL
    admission (``max_queue`` saturation, EDF ordering, deadline shedding)
    and fans requests out to N :class:`ServeEngine` replicas behind a
    :class:`ReplicaTransport` seam (in-process engines by default, so a
    whole fleet is Tier-1-testable in one process). Replicas run with
    unbounded engine queues and no deadlines: the router is the fleet's
    single gatekeeper, per-replica :class:`ChunkScheduler` instances keep
    ordering prefill chunks inside each engine.
  * **replica lifecycle** — STARTING -> HEALTHY -> DRAINING -> DEAD
    (:class:`repro.serve.fleet.Replica`), driven by per-step heartbeats
    on the injectable ``ServeConfig.clock``. STARTING replicas take no
    traffic until their first probe; DRAINING replicas finish their
    in-flight work and retire; fail-stop (missed heartbeat or
    :class:`ReplicaDead` mid-call) is terminal and loses ALL replica
    state — recovery reads nothing back from the dead engine.
  * **migration guarantees** — the router keeps its own census (what it
    dispatched where, every token streamed back), so on fail-stop each
    affected request re-enters the queue: never-started requests replay;
    decoding requests resume from their generated-token prefix via ONE
    batched prefill of ``prompt + prefix`` (cost independent of decode
    steps already spent — the no-rollback property); when the prefix
    outgrows the largest bucket, the original prompt is recomputed and
    the regenerated prefix suppressed at drain time. The caller's
    :class:`RequestHandle`/:class:`TokenRing` surface stays valid across
    migration — the iterator never learns a replica died, never repeats
    a token, and (greedy decode being deterministic, prefill/decode
    paths bit-identical) streams EXACTLY the no-failure run's tokens.
    What is NOT preserved: wall-clock latency (a migrated request pays
    queue re-entry + one context prefill) and engine-level metrics of
    the dead replica (the router's counters survive; the engine's die
    with it).
  * **autoscaling + warm spawn** — :class:`ScalingPolicy` spawns a
    replica when router queue depth outruns the healthy pool and drains
    one when utilization (``metrics['packed_tokens']`` against the token
    budget, or slot occupancy) falls below a floor. Spawned replicas
    reuse the first replica's :meth:`ServeEngine.warm_state` — shared
    slot census, :class:`~repro.ft.plans.CompiledPlans`, quantized
    protected weights, autotune winners — so scale-up under load never
    re-runs the startup census/sweep (``plans.misses == 0`` and zero new
    sweeps on every replica after the first).
"""
from repro.ft.heads import (ft_logits, ft_logits_decode, ft_logits_prefill,
                            quantize_head)
from repro.serve.engine import (Request, ServeConfig, ServeEngine,
                                geometric_buckets, resolve_buckets)
from repro.serve.fleet import (DEAD, DRAINING, HEALTHY, STARTING, Fleet,
                               FleetConfig, Replica, ScalingPolicy)
from repro.serve.reference import PerSlotEngine
from repro.serve.router import FleetRecord, Router
from repro.serve.scheduler import (AdmissionRejected, ChunkScheduler,
                                   DeadlineExceeded, RequestHandle,
                                   TokenRing)
from repro.serve.transport import (InProcessTransport, ReplicaDead,
                                   ReplicaTransport)

__all__ = [
    "AdmissionRejected",
    "ChunkScheduler",
    "DEAD",
    "DRAINING",
    "DeadlineExceeded",
    "Fleet",
    "FleetConfig",
    "FleetRecord",
    "HEALTHY",
    "InProcessTransport",
    "PerSlotEngine",
    "Replica",
    "ReplicaDead",
    "ReplicaTransport",
    "Request",
    "RequestHandle",
    "Router",
    "STARTING",
    "ScalingPolicy",
    "ServeConfig",
    "ServeEngine",
    "TokenRing",
    "ft_logits",
    "ft_logits_decode",
    "ft_logits_prefill",
    "geometric_buckets",
    "quantize_head",
    "resolve_buckets",
]
