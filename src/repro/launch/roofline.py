"""Roofline analysis over dry-run artifacts (task deliverable g).

Per (arch x shape-cell) on the single-pod 16x16 mesh (and optionally
multi-pod), derives the three roofline terms from the compiled per-device
HLO via the trip-count-aware cost model (repro.launch.hlo_cost):

  compute_s    = flops_per_device    / PEAK_FLOPS     (197 TFLOP/s bf16)
  memory_s     = bytes_per_device    / HBM_BW         (819 GB/s)
  collective_s = coll_bytes_per_dev  / LINK_BW        (50 GB/s/link ICI)

(The prompt's global form HLO_FLOPs/(chips x peak) equals the per-device
form for balanced SPMD programs — compiled HLO is already per-device.)

Also reports MODEL_FLOPS = 6 N D (train) / 2 N D (inference), N_active for
MoE, and the useful-compute fraction MODEL_FLOPS / global HLO FLOPs.
"""
from __future__ import annotations

import argparse
import gzip
import json
import pathlib
import re

import jax

from repro.configs import get_config
from repro.launch import input_specs as ispecs
from repro.launch.hlo_cost import analyze_text

PEAK_FLOPS = 197e12  # bf16 TPU v5e
HBM_BW = 819e9
LINK_BW = 50e9

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts"


def param_counts(arch: str, max_seq: int = 4096) -> dict:
    """Exact parameter counts from the eval_shape tree (no allocation)."""
    cfg = get_config(arch)
    specs = ispecs.params_specs(cfg, max_seq=max_seq)
    total = emb = expert = 0
    for kp, leaf in jax.tree_util.tree_flatten_with_path(specs)[0]:
        path = jax.tree_util.keystr(kp)
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
        if re.search(r"'tok'|'head'|'pos'", path):
            emb += n
        if re.search(r"we_gate|we_up|we_down", path):
            expert += n
    active = total
    if cfg.moe:
        active = total - expert + expert * cfg.moe.top_k / cfg.moe.n_experts
    return {"total": total, "embedding": emb, "expert": expert,
            "active": active, "nonemb": total - emb,
            "active_nonemb": active - emb}


def model_flops(arch: str, cell: dict, counts: dict) -> float:
    tokens = cell["global_batch"] * (cell["seq_len"] if cell["kind"] != "decode" else 1)
    n = counts["active_nonemb"]
    mult = 6.0 if cell["kind"] == "train" else 2.0
    return mult * n * tokens


def analyze_cell(json_path: pathlib.Path) -> dict | None:
    rec = json.loads(json_path.read_text())
    if not rec.get("ok"):
        return None
    hlo_path = json_path.with_suffix("").with_suffix("")  # strip .json
    hlo_path = json_path.parent / (json_path.stem + ".hlo.gz")
    with gzip.open(hlo_path, "rt") as f:
        text = f.read()
    cost = analyze_text(text)
    chips = 512 if "multipod" in rec["mesh"] else 256
    counts = param_counts(rec["arch"], max_seq=min(rec["seq_len"], 4096))
    mf = model_flops(rec["arch"], rec, counts)
    flops_global = cost["flops_per_device"] * chips
    compute_s = cost["flops_per_device"] / PEAK_FLOPS
    memory_s = cost["bytes_per_device"] / HBM_BW
    coll_s = cost["collective_bytes_per_device"] / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bound = max(terms, key=terms.get)
    return {
        "arch": rec["arch"], "cell": rec["cell"], "mesh": rec["mesh"],
        "kind": rec["kind"], "chips": chips,
        "flops_per_device": cost["flops_per_device"],
        "bytes_per_device": cost["bytes_per_device"],
        "collective_bytes_per_device": cost["collective_bytes_per_device"],
        "collective_counts": cost["collective_counts"],
        "compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s,
        "bound": bound,
        "model_flops": mf,
        "useful_frac": mf / flops_global if flops_global else 0.0,
        "params_total": counts["total"], "params_active": counts["active"],
        "temp_bytes_per_device": rec.get("memory_analysis", {}).get(
            "temp_size_in_bytes", 0),
        "arg_bytes_per_device": rec.get("memory_analysis", {}).get(
            "argument_size_in_bytes", 0),
    }


_ADVICE = {
    "compute": "compute-bound: raise MXU utilization (fuse small ops, grow "
               "per-device tile sizes) or cut redundant FLOPs (causal flash "
               "block-skip, absorbed MLA projections)",
    "memory": "memory-bound: shrink bytes/step — lower-precision states, "
              "fewer activation round-trips (fusion), int8/bf16 weights, "
              "larger arithmetic intensity per HBM load",
    "collective": "collective-bound: reshard to cut cross-device traffic "
                  "(EP all-to-all instead of allgather, overlap collectives "
                  "with compute, gradient compression)",
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod_16x16")
    ap.add_argument("--dryrun-dir", default=str(ART / "dryrun"))
    ap.add_argument("--out", default=str(ART / "roofline"))
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    rows = []
    for jp in sorted(pathlib.Path(args.dryrun_dir).glob(f"*__{args.mesh}.json")):
        row = analyze_cell(jp)
        if row:
            rows.append(row)
            print(f"{row['arch']:22s} {row['cell']:12s} "
                  f"C={row['compute_s']:.2e}s M={row['memory_s']:.2e}s "
                  f"X={row['collective_s']:.2e}s -> {row['bound']:10s} "
                  f"useful={row['useful_frac']:.2f}")
    (out_dir / "roofline.json").write_text(json.dumps(rows, indent=1))

    # markdown table for EXPERIMENTS.md
    lines = [
        "| arch | cell | compute s | memory s | collective s | bound | "
        "MODEL_FLOPs | useful frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['cell']} | {r['compute_s']:.2e} | "
            f"{r['memory_s']:.2e} | {r['collective_s']:.2e} | {r['bound']} | "
            f"{r['model_flops']:.2e} | {r['useful_frac']:.3f} | "
            f"{_ADVICE[r['bound']].split(':')[0]} |")
    (out_dir / "roofline.md").write_text("\n".join(lines) + "\n")
    print(f"[roofline] {len(rows)} cells -> {out_dir}")


if __name__ == "__main__":
    main()
