"""ShapeDtypeStruct stand-ins for every (arch x shape-cell) step input.

No device allocation: the dry-run lowers/compiles against these specs only.
VLM/audio frontends are stubs per task spec: input_specs provides precomputed
patch/frame embeddings alongside tokens.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell
from repro.models import layers as L
from repro.models.api import get_model

SDS = jax.ShapeDtypeStruct


def _batch_specs(cfg: ModelConfig, cell: ShapeCell, kind: str) -> dict[str, Any]:
    B = cell.global_batch
    T = cell.seq_len
    batch: dict[str, Any] = {}
    if cfg.family == "vlm":
        P = cfg.vision.n_patches
        T_text = max(T - P, 1)
        batch["patch_embeds"] = SDS((B, P, cfg.d_model), L.ACT_DTYPE)
        batch["tokens"] = SDS((B, T_text), jnp.int32)
    elif cfg.family == "encdec":
        batch["frames"] = SDS((B, cfg.encoder.n_frames, cfg.d_model), L.ACT_DTYPE)
        batch["tokens"] = SDS((B, T), jnp.int32)
    else:
        batch["tokens"] = SDS((B, T), jnp.int32)
    if kind == "train":
        batch["loss_mask"] = SDS(batch["tokens"].shape, jnp.int32)
    return batch


def params_specs(cfg: ModelConfig, max_seq: int):
    model = get_model(cfg)
    return jax.eval_shape(
        lambda k: model.init(k, cfg, max_seq=max_seq), jax.random.PRNGKey(0)
    )


def cache_specs(cfg: ModelConfig, batch: int, max_seq: int):
    model = get_model(cfg)
    return jax.eval_shape(lambda: model.init_cache(cfg, batch, max_seq))


def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict[str, Any]:
    """Everything the cell's step function consumes, as specs.

    train:   {'batch': ...}                          for train_step(state, batch)
    prefill: {'batch': ..., 'cache': ...}            for prefill(params, batch, cache)
    decode:  {'tok': ..., 'cache': ..., 'pos': ...}  for decode_step(...)
    """
    if cell.kind == "train":
        return {"batch": _batch_specs(cfg, cell, "train")}
    if cell.kind == "prefill":
        return {
            "batch": _batch_specs(cfg, cell, "prefill"),
            "cache": cache_specs(cfg, cell.global_batch, cell.seq_len),
        }
    if cell.kind == "decode":
        return {
            "tok": SDS((cell.global_batch, 1), jnp.int32),
            "cache": cache_specs(cfg, cell.global_batch, cell.seq_len),
            "pos": SDS((), jnp.int32),
        }
    raise ValueError(cell.kind)
