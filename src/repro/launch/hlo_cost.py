"""Trip-count-aware cost model over compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body once* —
useless for scan-over-layers/scan-over-time programs (a 61-layer model would
report 1 layer of FLOPs). This parser walks the HLO text instead:

  * dot/convolution FLOPs from operand/output shapes,
  * elementwise FLOPs inside fusion computations,
  * HBM bytes: operands+outputs of top-level memory ops (fusion internals
    stay in registers/VMEM),
  * collective bytes: operand sizes of all-reduce / all-gather /
    reduce-scatter / all-to-all / collective-permute (async -start included),
  * while bodies multiplied by ``backend_config known_trip_count`` (scan).

Compiled HLO is the PER-DEVICE program (post-partitioning shapes), so all
totals are per-device; multiply by chip count for global figures.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_ELEMENTWISE_FLOP_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "rsqrt", "sqrt", "negate", "abs", "floor",
    "ceil", "sign", "atan2", "expm1", "log1p", "logistic", "cosine", "sine",
    "compare", "select", "and", "or", "xor", "not", "shift-left",
    "shift-right-arithmetic", "shift-right-logical", "clamp", "remainder",
    "round-nearest-afz", "round-nearest-even", "cbrt", "erf",
}

_MEM_OPS = {
    "fusion", "dot", "convolution", "custom-call", "copy", "slice",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "broadcast",
    "transpose", "reduce", "sort", "gather", "scatter", "pad", "reverse",
    "reduce-window", "select-and-scatter", "iota", "rng", "cholesky",
    "triangular-solve", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start", "ragged-all-to-all",
}


def _shape_bytes(shape: str) -> float:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0.0
    for m in re.finditer(r"(\w[\w$]*)\[([\d,]*)\]", shape):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(shape: str) -> int:
    m = re.search(r"\w+\[([\d,]*)\]", shape)
    if not m:
        return 1
    n = 1
    if m.group(1):
        for d in m.group(1).split(","):
            n *= int(d)
    return n


def _shape_dims(shape: str) -> list[int]:
    m = re.search(r"\w+\[([\d,]*)\]", shape)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    operands: list[str]
    attrs: str
    raw_args: str = ""
    is_root: bool = False


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: Optional[dict] = None

    def __add__(self, o: "Cost") -> "Cost":
        cc = dict(self.coll_counts or {})
        for k, v in (o.coll_counts or {}).items():
            cc[k] = cc.get(k, 0) + v
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.coll_bytes + o.coll_bytes, cc)

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f, self.coll_bytes * f,
                    {k: v * f for k, v in (self.coll_counts or {}).items()})


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[Instr]] = {}
        self._parse(hlo_text)
        self._memo: dict[str, Cost] = {}

    # ------------------------------------------------------------ parsing --

    def _parse(self, text: str):
        current: Optional[str] = None
        for raw in text.splitlines():
            line = raw.strip()
            if not line or line.startswith("//"):
                continue
            head = re.match(r"^(ENTRY\s+)?%?([\w.\-$]+)\s*\(.*\)\s*->.*\{$", line)
            if head and " = " not in line:
                current = head.group(2)
                self.computations[current] = []
                if head.group(1):
                    self.entry = current
                continue
            if line == "}" or line.startswith("}"):
                continue
            m = re.match(r"^(ROOT\s+)?%?([\w.\-$]+)\s*=\s*(.*)$", line)
            if not m or current is None:
                continue
            is_root, name, rest = bool(m.group(1)), m.group(2), m.group(3)
            # type: up to the op name; tuples need balanced parens
            rest = rest.strip()
            if rest.startswith("("):
                depth = 0
                for i, ch in enumerate(rest):
                    depth += ch == "("
                    depth -= ch == ")"
                    if depth == 0:
                        break
                shape, rest2 = rest[: i + 1], rest[i + 1 :].strip()
            else:
                sp = rest.find(" ")
                shape, rest2 = rest[:sp], rest[sp + 1 :].strip()
            om = re.match(r"^([\w\-]+)\((.*)$", rest2)
            if not om:
                continue
            op = om.group(1)
            # split args from attrs at the matching close paren
            body = om.group(2)
            depth, i = 1, 0
            for i, ch in enumerate(body):
                depth += ch == "("
                depth -= ch == ")"
                if depth == 0:
                    break
            args, attrs = body[:i], body[i + 1 :]
            operands = re.findall(r"%([\w.\-$]+)", args)
            self.computations[current].append(
                Instr(name, shape, op, operands, attrs, args, is_root))

    # ---------------------------------------------------------- accounting --

    def _symtab(self, comp: str) -> dict[str, str]:
        return {i.name: i.shape for i in self.computations[comp]}

    def _dot_flops(self, instr: Instr, sym: dict[str, str]) -> float:
        out = _shape_elems(instr.shape)
        lhs_shape = sym.get(instr.operands[0], "")
        dims = _shape_dims(lhs_shape)
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
        contract = 1
        if cm and cm.group(1):
            for d in cm.group(1).split(","):
                if int(d) < len(dims):
                    contract *= dims[int(d)]
        return 2.0 * out * contract

    def _conv_flops(self, instr: Instr, sym: dict[str, str]) -> float:
        out = _shape_elems(instr.shape)
        rhs = sym.get(instr.operands[1], "")
        kelems = _shape_elems(rhs)
        rdims = _shape_dims(rhs)
        out_feat = rdims[-1] if rdims else 1
        return 2.0 * out * max(kelems // max(out_feat, 1), 1)

    def _trip_count(self, instr: Instr) -> float:
        m = re.search(r'known_trip_count[^\d]*(\d+)', instr.attrs)
        return float(m.group(1)) if m else 1.0

    def _called(self, instr: Instr, key: str) -> Optional[str]:
        m = re.search(key + r"=%?([\w.\-$]+)", instr.attrs)
        return m.group(1) if m else None

    def _mem_bytes(self, ins: Instr, sym: dict[str, str]) -> float:
        """HBM traffic estimate per op. Windowed reads (dynamic-slice,
        gather) move only their OUTPUT-sized window, not the full operand —
        critical inside scan bodies where operand bytes would be multiplied
        by the trip count."""
        out = _shape_bytes(ins.shape)
        op = ins.op
        if op in ("dynamic-slice", "slice", "gather", "broadcast", "iota",
                  "reverse", "pad", "rng"):
            return 2.0 * out  # read window + write result
        if op == "dynamic-update-slice":
            upd = _shape_bytes(sym.get(ins.operands[1], "")) if len(
                ins.operands) > 1 else out
            return 2.0 * upd  # in-place read-modify-write of the window
        if op == "scatter":
            upd = _shape_bytes(sym.get(ins.operands[-1], "")) if ins.operands else out
            return 3.0 * upd  # read target window + update + write
        if op in ("copy", "transpose"):
            return 2.0 * out
        if op in ("concatenate", "sort", "reduce-window", "select-and-scatter"):
            return 2.0 * out + sum(
                _shape_bytes(sym.get(o, "")) for o in set(ins.operands)
                if o in sym and _shape_bytes(sym[o]) <= out)
        if op == "fusion":
            callee = self._called(ins, "calls")
            if callee and callee in self.computations:
                return self._fusion_io_bytes(callee, ins, sym)
        # dot / convolution / custom-call / reduce / collectives:
        # full operand reads + output write
        opb = sum(_shape_bytes(sym.get(o, "")) for o in set(ins.operands)
                  if o in sym)
        return opb + out

    _SLICING = {"dynamic-slice", "gather", "slice"}

    def _fusion_io_bytes(self, callee: str, ins: Instr, sym: dict[str, str]) -> float:
        """True I/O of a fusion: parameters consumed ONLY through slicing ops
        inside the fusion move a window, not the whole array (critical for
        scan bodies, where XLA fuses the per-step dynamic-slice into the
        consumer and the 'operand' is the full stacked xs array). A root
        dynamic-update-slice writes its update window, not the buffer."""
        body = self.computations[callee]
        # parameter index -> name; consumers map
        consumers: dict[str, list[Instr]] = {}
        params: dict[str, int] = {}
        for bi in body:
            if bi.op == "parameter":
                try:
                    params[bi.name] = int(bi.raw_args.strip() or 0)
                except ValueError:
                    params[bi.name] = 0
            for o in bi.operands:
                consumers.setdefault(o, []).append(bi)

        read = 0.0
        for pname, pidx in params.items():
            full = _shape_bytes(
                sym.get(ins.operands[pidx], "") if pidx < len(ins.operands)
                else "")
            cons = consumers.get(pname, [])
            if cons and all(c.op in self._SLICING for c in cons):
                read += sum(_shape_bytes(c.shape) for c in cons)
            elif cons and all(c.op in self._SLICING or c.op ==
                              "dynamic-update-slice" for c in cons):
                # DUS target: in-place, charge the update windows
                read += sum(
                    _shape_bytes(self._body_shape(body, c.operands[1]))
                    for c in cons if c.op == "dynamic-update-slice")
            else:
                read += full

        root = next((bi for bi in body if bi.is_root), body[-1] if body else None)
        write = _shape_bytes(ins.shape)
        if root is not None and root.op == "dynamic-update-slice":
            write = _shape_bytes(self._body_shape(body, root.operands[1]))
        elif root is not None and root.op == "tuple":
            w = 0.0
            for o in root.operands:
                d = next((bi for bi in body if bi.name == o), None)
                if d is not None and d.op == "dynamic-update-slice":
                    w += _shape_bytes(self._body_shape(body, d.operands[1]))
                elif d is not None:
                    w += _shape_bytes(d.shape)
            write = w
        elif root is not None and root.op == "bitcast" and root.operands:
            d = next((bi for bi in body if bi.name == root.operands[0]), None)
            if d is not None and d.op == "dynamic-update-slice":
                write = _shape_bytes(self._body_shape(body, d.operands[1]))
        return read + write

    @staticmethod
    def _body_shape(body: list, name: str) -> str:
        for bi in body:
            if bi.name == name:
                return bi.shape
        return ""

    def comp_cost(self, comp: str, mem_level: bool = True) -> Cost:
        """mem_level=False inside fusions: internals cost flops, not bytes."""
        key = f"{comp}|{mem_level}"
        if key in self._memo:
            return self._memo[key]
        sym = self._symtab(comp)
        total = Cost(coll_counts={})
        for ins in self.computations.get(comp, []):
            c = Cost(coll_counts={})
            if ins.op == "dot":
                c.flops = self._dot_flops(ins, sym)
            elif ins.op == "convolution":
                c.flops = self._conv_flops(ins, sym)
            elif ins.op in _ELEMENTWISE_FLOP_OPS:
                c.flops = float(_shape_elems(ins.shape))
            elif ins.op == "while":
                body = self._called(ins, "body")
                cond = self._called(ins, "condition")
                trip = self._trip_count(ins)
                inner = self.comp_cost(body, mem_level)
                if cond:
                    inner = inner + self.comp_cost(cond, mem_level)
                c = inner.scaled(trip)
            elif ins.op == "fusion":
                callee = self._called(ins, "calls")
                if callee:
                    c = self.comp_cost(callee, mem_level=False)
                    c = Cost(c.flops, 0.0, c.coll_bytes, c.coll_counts)
            elif ins.op in ("call", "async-start"):
                callee = self._called(ins, "to_apply") or self._called(ins, "calls")
                if callee:
                    c = self.comp_cost(callee, mem_level)
            elif ins.op == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}", ins.attrs)
                names = re.findall(r"%?([\w.\-$]+)", branches[0]) if branches else []
                tb = self._called(ins, "true_computation")
                fb = self._called(ins, "false_computation")
                names += [x for x in (tb, fb) if x]
                if names:
                    costs = [self.comp_cost(n, mem_level) for n in names]
                    c = max(costs, key=lambda x: x.flops)
            elif ins.op in ("reduce", "reduce-window", "scatter",
                            "select-and-scatter", "sort", "map"):
                callee = self._called(ins, "to_apply")
                if callee:
                    per = self.comp_cost(callee, mem_level=False).flops
                    c.flops = per * _shape_elems(
                        sym.get(ins.operands[0], ins.shape))

            if ins.op in _COLLECTIVES:
                opb = sum(
                    _shape_bytes(sym.get(o, "")) for o in ins.operands
                    if o in sym)
                c.coll_bytes += opb
                c.coll_counts = {ins.op.replace("-start", ""): 1}

            if mem_level and ins.op in _MEM_OPS:
                c.bytes += self._mem_bytes(ins, sym)
            total = total + c
        self._memo[key] = total
        return total

    def entry_cost(self) -> Cost:
        return self.comp_cost(self.entry)


def analyze_text(hlo_text: str) -> dict:
    model = HloCostModel(hlo_text)
    c = model.entry_cost()
    return {
        "flops_per_device": c.flops,
        "bytes_per_device": c.bytes,
        "collective_bytes_per_device": c.coll_bytes,
        "collective_counts": c.coll_counts or {},
    }
