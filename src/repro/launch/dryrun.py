import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape-cell) on the
production meshes, prove sharding coherence, record memory/cost/HLO
artifacts for the roofline analysis.

MUST be imported before any other jax-touching module — the device-count
flag above is locked in at first jax init (hence the unusual import order).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --cell train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both            # everything
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both --variant opt
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import gzip  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, cells_for, get_config  # noqa: E402
from repro.configs.base import ModelConfig, ShapeCell  # noqa: E402
from repro.dist import params as dparams  # noqa: E402
from repro.dist.sharding import axis_rules  # noqa: E402
from repro.launch import input_specs as ispecs  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.api import get_model  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.train.train_step import TrainConfig, make_train_step  # noqa: E402

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _train_cfg_for(cfg: ModelConfig) -> TrainConfig:
    # bf16 Adam moments for the very large models (see DESIGN.md §7)
    big = cfg.name.startswith("deepseek-v3") or cfg.name.startswith("granite")
    return TrainConfig(
        adamw=AdamWConfig(state_dtype="bfloat16" if big else None),
        max_seq=4096,
    )


# --variant opt: the §Perf-optimized configuration (EXPERIMENTS.md logs the
# baseline -> opt deltas per hillclimbed cell).
_OPT_GRAD_ACCUM = {"deepseek-v3-671b": 8, "granite-20b": 4}


def _apply_variant(cfg: ModelConfig, tcfg, cell, variant: str):
    if variant == "opt":
        cfg = dataclasses.replace(cfg, norm_f32=False, loss_impl="streamed",
                                  mla_absorb=True)
        if tcfg is not None and cell.kind == "train":
            ga = _OPT_GRAD_ACCUM.get(cfg.name, 1)
            tcfg = dataclasses.replace(tcfg, grad_accum=ga)
    return cfg, tcfg


def build(cfg: ModelConfig, cell: ShapeCell, mesh, variant: str = "baseline"):
    """Returns (fn, arg_specs tuple, in_shardings, out_shardings, donate)."""
    model = get_model(cfg)
    specs = ispecs.input_specs(cfg, cell)

    if cell.kind == "train":
        tcfg = _train_cfg_for(cfg)
        cfg_t = dataclasses.replace(cfg, remat="full")
        cfg_t, tcfg = _apply_variant(cfg_t, tcfg, cell, variant)
        step = make_train_step(cfg_t, tcfg)
        p_specs = ispecs.params_specs(cfg_t, max_seq=cell.seq_len)
        p_sh = dparams.param_shardings(cfg_t, mesh, p_specs)
        state_specs = {
            "params": p_specs,
            "opt": {
                "m": jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(
                        s.shape,
                        jnp.bfloat16 if tcfg.adamw.state_dtype else jnp.float32),
                    p_specs),
                "v": jax.tree.map(
                    lambda s: jax.ShapeDtypeStruct(
                        s.shape,
                        jnp.bfloat16 if tcfg.adamw.state_dtype else jnp.float32),
                    p_specs),
            },
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        state_sh = {
            "params": p_sh,
            "opt": {"m": p_sh, "v": p_sh},
            "step": NamedSharding(mesh, P()),
        }
        b_sh = dparams.batch_shardings(mesh, specs["batch"])
        fn = step
        args = (state_specs, specs["batch"])
        in_sh = (state_sh, b_sh)
        out_sh = (state_sh, None)
        donate = (0,)
        return fn, args, in_sh, out_sh, donate, cfg_t

    cfg, _ = _apply_variant(cfg, None, cell, variant)
    p_specs = ispecs.params_specs(cfg, max_seq=cell.seq_len)
    p_sh = dparams.param_shardings(cfg, mesh, p_specs)
    c_sh = dparams.cache_shardings(cfg, mesh, specs["cache"])

    if cell.kind == "prefill":
        def fn(params, batch, cache):
            return model.prefill(params, batch, cfg, cache)

        b_sh = dparams.batch_shardings(mesh, specs["batch"])
        args = (p_specs, specs["batch"], specs["cache"])
        in_sh = (p_sh, b_sh, c_sh)
        out_sh = (None, c_sh)
        return fn, args, in_sh, out_sh, (2,), cfg

    def fn(params, tok, cache, pos):
        return model.decode_step(params, tok, cache, pos, cfg)

    tok_sh = dparams.batch_shardings(mesh, specs["tok"])
    args = (p_specs, specs["tok"], specs["cache"], specs["pos"])
    in_sh = (p_sh, tok_sh, c_sh, NamedSharding(mesh, P()))
    out_sh = (None, c_sh)
    return fn, args, in_sh, out_sh, (2,), cfg


def run_cell(arch: str, cell: ShapeCell, multi_pod: bool, out_dir: pathlib.Path,
             save_hlo: bool = True, variant: str = "baseline") -> dict:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    tag = f"{arch}__{cell.name}__{mesh_name}"
    rec: dict = {"arch": arch, "cell": cell.name, "mesh": mesh_name,
                 "seq_len": cell.seq_len, "global_batch": cell.global_batch,
                 "kind": cell.kind}
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    out_dir.mkdir(parents=True, exist_ok=True)
    t0 = time.monotonic()
    try:
        with mesh, axis_rules(mesh):
            fn, args, in_sh, out_sh, donate, cfg_used = build(
                cfg, cell, mesh, variant=variant)
            jitted = jax.jit(
                fn, in_shardings=in_sh, out_shardings=out_sh,
                donate_argnums=donate)
            lowered = jitted.lower(*args)
            rec["lower_s"] = round(time.monotonic() - t0, 1)
            t1 = time.monotonic()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.monotonic() - t1, 1)
            mem = compiled.memory_analysis()
            print(mem)
            cost = compiled.cost_analysis()
            print({k: cost.get(k) for k in ("flops", "bytes accessed")})
            rec["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "alias_size_in_bytes",
                          "generated_code_size_in_bytes")
                if hasattr(mem, k)
            }
            rec["cost_analysis"] = {
                k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or "utilization" not in k)
            } if cost else {}
            rec["ok"] = True
            if save_hlo:
                hlo = compiled.as_text()
                with gzip.open(out_dir / f"{tag}.hlo.gz", "wt") as f:
                    f.write(hlo)
                rec["hlo_bytes"] = len(hlo)
    except Exception as e:  # record failures — they are bugs to fix
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.monotonic() - t0, 1)
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{tag}.json").write_text(json.dumps(rec, indent=1))
    status = "OK" if rec.get("ok") else f"FAIL ({rec.get('error', '')[:120]})"
    print(f"[dryrun] {tag}: {status} ({rec['total_s']}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--cell", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(ART_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=["baseline", "opt"])
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    out_dir = pathlib.Path(args.out)

    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        for cell in cells_for(cfg):
            if args.cell != "all" and cell.name not in args.cell.split(","):
                continue
            for mp in meshes:
                mesh_name = "multipod_2x16x16" if mp else "pod_16x16"
                tag = f"{arch}__{cell.name}__{mesh_name}"
                if args.skip_existing and (out_dir / f"{tag}.json").exists():
                    prev = json.loads((out_dir / f"{tag}.json").read_text())
                    if prev.get("ok"):
                        print(f"[dryrun] {tag}: cached OK")
                        n_ok += 1
                        continue
                rec = run_cell(arch, cell, mp, out_dir, save_hlo=not args.no_hlo,
                               variant=args.variant)
                n_ok += bool(rec.get("ok"))
                n_fail += not rec.get("ok")
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
