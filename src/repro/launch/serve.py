"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``

Boots the batched continuous-batching engine with random weights (or a
checkpoint directory) and runs a synthetic request wave. Fault tolerance is
first-class: ``--ft-mode entangle`` turns on the fused entangled int8 head
GEMM on every decode step AND on every admission batch's first token
(slot -> group = slot % ft_M), ``--ft-scope`` widens protection to the
in-model projections (``qkv`` | ``mlp`` | ``out`` | ``moe`` | ``all`` —
QKV, MLP up/down + router, output projections and MoE per-expert GEMMs
run entangled through the repro.ft subsystem; protection plans and weight
quantization are compiled once at startup), ``--failed-group r``
injects a fail-stop into group r's compute on every step, and ``--smoke``
prints a per-scope recovery summary (healthy vs injected outputs compared
token-by-token, for the head scope and the configured scope) plus the
engine's prefill/decode shape census and the autotune warmup counters.

Admission is the bucketed, chunked batched prefill pipeline:
``--prefill-buckets 8,16,32`` overrides the geometric default length
buckets, ``--prefill-chunk C`` interleaves C-token prefill chunks with
decode steps (0 = whole bucket per call), and ``--token-budget N`` turns
on token-packed admission — up to N prompt tokens per step, drawn from
ALL in-flight admission batches into ONE fixed-shape token-parallel
program (requires ``--prefill-chunk > 0``, N a multiple of it, and
``N / prefill-chunk <= max-batch`` rows; all checked at parse time).

Steady-state flags: ``--arrival-rate r`` replays a seeded open-loop
Poisson arrival trace (r requests/sec; 0 = submit the whole wave up
front), ``--deadline-ms d`` attaches an SLA to every request (queued
requests past it are shed loudly), ``--no-refill`` forces boundary
admission — new batches plan only when no admission batch is in flight
(the A/B baseline for mid-flight refill, which is the default).

Fleet flags: ``--replicas N`` (N > 1, or any fleet flag) serves the wave
through the multi-replica fabric (:mod:`repro.serve.fleet`) instead of a
single engine — router-owned admission, per-replica engines behind the
in-process transport. ``--kill-replica-at S --kill-replica R`` injects a
fail-stop into replica R at fleet step S (mid-wave machine loss; the
router migrates R's in-flight requests and the wave still completes),
``--max-replicas M --scale-up-depth D`` turns on queue-depth autoscaling
between the initial pool size and M. All cross-flag contracts are
validated at parse time.
"""
import argparse
import dataclasses
import time

import numpy as np
import jax

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.ft import SCOPES
from repro.kernels import autotune
from repro.models import get_model
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.fleet import Fleet, FleetConfig, ScalingPolicy
from repro.train.checkpoint import CheckpointManager

# shared drain bound for closed waves — kill/scaling schedules are
# validated against it at parse time so a mis-typed step count fails
# before engine startup rather than hanging a wave
MAX_WAVE_STEPS = 10_000


def _wave(eng: ServeEngine, n_requests: int, vocab: int, max_new: int,
          failed_group, arrival_rate: float = 0.0, deadline_ms=None):
    rng = np.random.default_rng(0)
    reqs = [Request(
        rid=r,
        prompt=rng.integers(0, vocab, size=8).astype(np.int32),
        max_new=max_new, deadline_ms=deadline_ms)
        for r in range(n_requests)]
    if not arrival_rate:
        for rq in reqs:
            eng.submit(rq)
        done = eng.run_to_completion(max_steps=MAX_WAVE_STEPS,
                                     failed_group=failed_group)
        return {r.rid: np.asarray(r.out) for r in done}
    # open-loop: submit each request at its seeded Poisson arrival time
    # (wall clock), stepping the engine in between — requests keep
    # arriving whether or not earlier ones have drained
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate,
                                         size=n_requests))
    t0, i, steps = time.monotonic(), 0, 0
    while i < n_requests or not eng.idle():
        now = time.monotonic() - t0
        if i < n_requests and eng.idle() and arrivals[i] > now:
            time.sleep(arrivals[i] - now)  # nothing to serve yet
            now = time.monotonic() - t0
        while i < n_requests and arrivals[i] <= now:
            eng.submit(reqs[i])
            i += 1
        eng.step(failed_group=failed_group)
        steps += 1
        assert steps < MAX_WAVE_STEPS, "open-loop wave failed to drain"
    if any(r.status == "shed" for r in reqs):
        print(f"[launch.serve] shed "
              f"{sum(r.status == 'shed' for r in reqs)} queued requests "
              f"past --deadline-ms {deadline_ms}")
    return {r.rid: np.asarray(r.out) for r in reqs if r.status == "done"}


def _fleet_wave(cfg, scfg: ServeConfig, params, args, failed_group):
    """Serve the synthetic wave through the multi-replica fabric, with an
    optional scheduled replica fail-stop, and print the migration
    summary. The wave must complete every request even when a replica is
    killed mid-flight — an incomplete wave exits nonzero."""
    pol = None
    if args.max_replicas:
        pol = ScalingPolicy(min_replicas=args.replicas,
                            max_replicas=args.max_replicas,
                            scale_up_depth=args.scale_up_depth)
    fleet = Fleet(cfg, scfg, params,
                  FleetConfig(replicas=args.replicas, policy=pol))
    rng = np.random.default_rng(0)
    reqs = [Request(
        rid=r,
        prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
        max_new=args.max_new, deadline_ms=args.deadline_ms)
        for r in range(args.requests)]
    for rq in reqs:
        fleet.submit(rq)
    steps = 0
    while not fleet.idle():
        if steps == args.kill_replica_at:
            print(f"[launch.serve] killing replica {args.kill_replica} "
                  f"at fleet step {steps} (fail-stop injected)")
            fleet.kill_replica(args.kill_replica)
        fleet.step(failed_group=failed_group)
        steps += 1
        assert steps < MAX_WAVE_STEPS, "fleet wave failed to drain"
    m = fleet.fleet_metrics()
    states = {rid: rep["state"] for rid, rep in m["replicas"].items()}
    done = sum(r.status == "done" for r in reqs)
    print(f"[launch.serve] fleet: {done}/{args.requests} requests "
          f"completed in {steps} fleet steps over {m['spawned']} replicas "
          f"(states: {states})")
    print(f"[launch.serve] fleet migration summary: "
          f"failed={m['failed']} migrated={m['router_migrated']} "
          f"(prefix-resume={m['router_resume_prefix']}, "
          f"recompute={m['router_resume_recompute']}, "
          f"replayed={m['router_replayed']}) "
          f"scale_ups={m['scale_ups']} scale_downs={m['scale_downs']} "
          f"shed={m['router_shed']}")
    if done + sum(r.status == "shed" for r in reqs) != args.requests:
        raise SystemExit(1)


def _validate_args(ap: argparse.ArgumentParser, args) -> None:
    """Fail FT/admission misconfigurations loudly at PARSE time.

    Every one of these would otherwise surface deep inside engine startup
    or a traced step (a mid-wave shape error, a silent mod-M wrap of the
    injected group, an autotune sweep of an impossible plan) — the
    launcher is the first place all the flags meet, so it owns the
    cross-flag contracts. ``--ft-scope`` itself is validated by argparse
    ``choices`` against the one true scope set (``repro.ft.SCOPES``).
    Returns the parsed ``--prefill-buckets`` tuple (or None) so ``main``
    consumes the exact value that was validated."""
    if args.ft_mode == "entangle":
        if args.ft_M < 3:
            ap.error(f"--ft-M must be >= 3 (the paper's minimum stream "
                     f"count), got {args.ft_M}")
        if args.max_batch % args.ft_M:
            ap.error(f"--max-batch ({args.max_batch}) must be divisible "
                     f"by --ft-M ({args.ft_M}): slots map round-robin "
                     f"onto the M entangled request groups")
    if args.failed_group >= 0:
        if args.ft_mode != "entangle":
            ap.error("--failed-group requires --ft-mode entangle")
        if args.failed_group >= args.ft_M:
            ap.error(f"--failed-group must be < --ft-M ({args.ft_M}); the "
                     f"kernel indexes streams mod M, so wrapping silently "
                     f"would drill a different group than requested")
    if args.prefill_chunk < 0:
        ap.error(f"--prefill-chunk must be >= 0, got {args.prefill_chunk}")
    if args.token_budget < 0:
        ap.error(f"--token-budget must be >= 0, got {args.token_budget}")
    if args.token_budget:
        # the packed program is [token_budget / prefill_chunk rows x
        # prefill_chunk tokens] — the budget must tile exactly into
        # chunk-wide rows, and every row stages in a distinct slot
        if args.prefill_chunk <= 0:
            ap.error(f"--token-budget ({args.token_budget}) requires "
                     f"--prefill-chunk > 0: packed rows are prefill-chunk "
                     f"tokens wide")
        if args.token_budget % args.prefill_chunk:
            ap.error(f"--token-budget ({args.token_budget}) must be a "
                     f"multiple of --prefill-chunk ({args.prefill_chunk}) "
                     f"— the packed program has ONE compiled shape, so "
                     f"the budget must tile exactly into chunk-wide rows")
        if args.token_budget // args.prefill_chunk > args.max_batch:
            ap.error(f"--token-budget/--prefill-chunk = "
                     f"{args.token_budget // args.prefill_chunk} packed "
                     f"rows > --max-batch ({args.max_batch}): every packed "
                     f"row stages in a distinct slot")
    buckets = None
    if args.prefill_buckets:
        try:
            buckets = tuple(int(b) for b in args.prefill_buckets.split(","))
        except ValueError:
            ap.error(f"--prefill-buckets must be comma-separated ints, "
                     f"got {args.prefill_buckets!r}")
        if any(b < 1 or b > args.max_seq for b in buckets):
            ap.error(f"--prefill-buckets {list(buckets)} must lie in "
                     f"[1, max-seq={args.max_seq}]")
    if args.arrival_rate < 0:
        ap.error(f"--arrival-rate must be >= 0 (requests/sec; 0 = closed "
                 f"wave), got {args.arrival_rate}")
    if args.deadline_ms is not None and args.deadline_ms <= 0:
        ap.error(f"--deadline-ms must be > 0, got {args.deadline_ms}")
    # -- fleet flags ---------------------------------------------------------
    if args.replicas < 1:
        ap.error(f"--replicas must be >= 1, got {args.replicas}")
    if args.max_replicas:
        if args.max_replicas < args.replicas:
            ap.error(f"--max-replicas ({args.max_replicas}) must be >= "
                     f"--replicas ({args.replicas}): autoscaling grows the "
                     f"pool above the initial size, never below it")
    if args.scale_up_depth < 1:
        ap.error(f"--scale-up-depth must be >= 1 (queued requests per "
                 f"healthy replica), got {args.scale_up_depth}")
    if args.kill_replica_at >= 0:
        if args.replicas < 2 and not args.max_replicas:
            ap.error(f"--kill-replica-at requires --replicas >= 2 or "
                     f"--max-replicas autoscaling: a surviving replica "
                     f"must absorb the migrated requests or the wave "
                     f"cannot drain")
        if args.kill_replica_at >= MAX_WAVE_STEPS:
            ap.error(f"--kill-replica-at ({args.kill_replica_at}) must be "
                     f"< {MAX_WAVE_STEPS}, the wave's drain bound — a "
                     f"later kill step would never fire")
        if not 0 <= args.kill_replica < args.replicas:
            ap.error(f"--kill-replica ({args.kill_replica}) must name a "
                     f"replica in the initial pool [0, {args.replicas})")
    elif args.kill_replica:
        ap.error(f"--kill-replica ({args.kill_replica}) requires "
                 f"--kill-replica-at to schedule the fail-stop")
    return buckets


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ft-mode", default="none", choices=["none", "entangle"],
                    help="entangle: fused entangled int8 head GEMM on every "
                         "decode step")
    ap.add_argument("--ft-M", type=int, default=4,
                    help="entangled request groups (max-batch %% ft-M == 0)")
    ap.add_argument("--ft-scope", default="head", choices=sorted(SCOPES),
                    help="which projections run entangled: head only, or "
                         "also the in-model QKV / MLP+router / output-proj "
                         "/ MoE-expert sites (all = everything)")
    ap.add_argument("--failed-group", type=int, default=-1,
                    help=">= 0: inject a fail-stop into this group's head "
                         "GEMM on every decode step (rolled forward "
                         "in-kernel)")
    ap.add_argument("--blocks", default="",
                    help="head-GEMM block sizes: '' (defaults) or 'auto' "
                         "(autotune warmup at startup)")
    ap.add_argument("--prefill-buckets", default="",
                    help="comma-separated prompt length buckets for batched "
                         "admission (default: geometric 8,16,...,max-seq)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help=">0: split bucketed prefill into chunks of this "
                         "many tokens, one chunk per engine step "
                         "(interleaved with decode)")
    ap.add_argument("--token-budget", type=int, default=0,
                    help=">0: token-packed admission — pack up to this "
                         "many prompt tokens per step from ALL in-flight "
                         "admission batches into one fixed-shape program "
                         "(requires --prefill-chunk > 0; must be a "
                         "multiple of it; budget/chunk rows <= max-batch)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help=">0: open-loop seeded Poisson arrivals at this "
                         "many requests/sec (0 = submit the whole wave "
                         "up front)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request SLA; queued requests past it are "
                         "shed loudly instead of served late")
    ap.add_argument("--no-refill", action="store_true",
                    help="boundary admission: plan new batches only when "
                         "no admission batch is in flight (disables "
                         "mid-flight slot refill)")
    ap.add_argument("--replicas", type=int, default=1,
                    help=">1 (or any fleet flag): serve through the "
                         "multi-replica fabric — router-owned admission "
                         "over this many in-process engine replicas")
    ap.add_argument("--kill-replica-at", type=int, default=-1,
                    help=">= 0: inject a whole-replica fail-stop at this "
                         "fleet step; the router migrates its in-flight "
                         "requests to healthy replicas")
    ap.add_argument("--kill-replica", type=int, default=0,
                    help="which replica id --kill-replica-at kills "
                         "(must lie in the initial pool)")
    ap.add_argument("--max-replicas", type=int, default=0,
                    help=">0: queue-depth autoscaling between --replicas "
                         "and this bound (0 = fixed-size pool)")
    ap.add_argument("--scale-up-depth", type=int, default=4,
                    help="autoscaling trigger: spawn a replica when the "
                         "router queue exceeds this many requests per "
                         "healthy replica")
    args = ap.parse_args()
    buckets = _validate_args(ap, args)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg, max_seq=args.max_seq)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        state_like = {"params": params}
        restored, step = mgr.restore(state_like)
        params = restored["params"]
        print(f"[launch.serve] restored params from step {step}")

    scfg = ServeConfig(
        max_batch=args.max_batch, max_seq=args.max_seq,
        ft_mode=args.ft_mode, ft_M=args.ft_M, ft_scope=args.ft_scope,
        blocks=(args.blocks or None),
        prefill_buckets=buckets, prefill_chunk=args.prefill_chunk,
        token_budget=args.token_budget, refill=not args.no_refill)
    failed = args.failed_group if args.failed_group >= 0 else None

    if (args.replicas > 1 or args.max_replicas > 0
            or args.kill_replica_at >= 0):
        _fleet_wave(cfg, scfg, params, args, failed)
        return

    eng = ServeEngine(cfg, scfg, params)
    outs = _wave(eng, args.requests, cfg.vocab_size, args.max_new, failed,
                 arrival_rate=args.arrival_rate,
                 deadline_ms=args.deadline_ms)
    first = list(outs[0][:8]) if 0 in outs else "<request 0 not completed>"
    print(f"[launch.serve] {len(outs)}/{args.requests} requests completed in "
          f"{eng.decode_calls} batched decode calls; first output: {first}")
    print(f"[launch.serve] shape census: {eng.census}")

    if args.smoke and args.ft_mode == "entangle":
        # per-scope recovery summary: drill the head scope AND the
        # configured scope (deduped). For the configured scope, the wave
        # above is one side of the comparison (healthy if no
        # --failed-group, injected otherwise) and only the missing side
        # runs; other scopes run both sides — every protected GEMM must
        # roll the failure forward so tokens match token-for-token.
        inj = failed if failed is not None else 0
        any_mismatch = False
        for scope in dict.fromkeys(["head", args.ft_scope]):
            sc = dataclasses.replace(scfg, ft_scope=scope)
            if scope == args.ft_scope:
                other = _wave(ServeEngine(cfg, sc, params), args.requests,
                              cfg.vocab_size, args.max_new,
                              inj if failed is None else None)
                healthy, injected = ((outs, other) if failed is None
                                     else (other, outs))
            else:
                healthy = _wave(ServeEngine(cfg, sc, params), args.requests,
                                cfg.vocab_size, args.max_new, None)
                injected = _wave(ServeEngine(cfg, sc, params), args.requests,
                                 cfg.vocab_size, args.max_new, inj)
            mismatches = sum(
                0 if np.array_equal(healthy[r], injected[r]) else 1
                for r in healthy)
            tokens = sum(len(v) for v in healthy.values())
            print(f"[launch.serve] recovery summary [scope={scope}]: "
                  f"failed_group={inj} injected on every step; "
                  f"{len(healthy)} requests / {tokens} tokens compared; "
                  f"mismatching requests: {mismatches} "
                  f"({'EXACT ROLL-FORWARD' if mismatches == 0 else 'RECOVERY FAILED'})")
            any_mismatch |= bool(mismatches)
        if args.blocks == "auto":
            print(f"[launch.serve] autotune: {autotune.stats()}; head-GEMM "
                  f"winners: {eng.census.get('head_gemm')}; protected "
                  f"sites warmed: {len(eng.census.get('protected', {}))}")
        if any_mismatch:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
