"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``

Boots the slot engine with random weights (or a checkpoint directory) and
runs a synthetic request wave; the same engine scales to the dry-run meshes
on real hardware.
"""
import argparse

import numpy as np
import jax

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import get_model
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.train.checkpoint import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg, max_seq=args.max_seq)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        state_like = {"params": params}
        restored, step = mgr.restore(state_like)
        params = restored["params"]
        print(f"[launch.serve] restored params from step {step}")

    eng = ServeEngine(cfg, ServeConfig(max_batch=args.max_batch,
                                       max_seq=args.max_seq), params)
    rng = np.random.default_rng(0)
    for r in range(args.requests):
        eng.submit(Request(
            rid=r,
            prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
            max_new=args.max_new))
    done = eng.run_to_completion()
    print(f"[launch.serve] {len(done)}/{args.requests} requests completed; "
          f"first output: {list(done[0].out[:8])}")


if __name__ == "__main__":
    main()
