"""Production mesh construction.

Defined as functions (NOT module-level constants) so importing this module
never touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) data x model = 256 chips.
    Multi-pod: (2, 16, 16) pod x data x model = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Whatever devices exist (CPU tests: 1 device), axes kept compatible."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def available_mesh(target_devices: int | None = None, *, multi_pod: bool = False):
    """Elastic helper: largest mesh constructible from surviving devices.

    After a pod/node loss, the trainer remeshes to the surviving device count
    and restores the latest checkpoint with resharding (train/checkpoint.py).
    """
    n = target_devices or len(jax.devices())
    if multi_pod and n >= 512:
        return make_production_mesh(multi_pod=True)
    if n >= 256:
        return make_production_mesh(multi_pod=False)
    # degrade: keep model axis <= 16, fold the rest into data
    model = min(16, n)
    while n % model:
        model //= 2
    return jax.make_mesh((n // model, model), ("data", "model"))
