"""Generates the EXPERIMENTS.md §Dry-run and §Roofline tables from dry-run
artifacts (baseline and opt variants)."""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.launch.roofline import analyze_cell

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts"


def rows_for(dirname: str, mesh: str):
    rows = {}
    d = ART / dirname
    for jp in sorted(d.glob(f"*__{mesh}.json")):
        r = analyze_cell(jp)
        if r:
            rows[(r["arch"], r["cell"])] = r
    return rows


def dryrun_table(dirname: str) -> str:
    lines = [
        "| arch | cell | mesh | status | lower s | compile s | "
        "args GB/dev | temp GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for jp in sorted((ART / dirname).glob("*.json")):
        rec = json.loads(jp.read_text())
        ma = rec.get("memory_analysis", {})
        lines.append(
            f"| {rec['arch']} | {rec['cell']} | {rec['mesh']} | "
            f"{'OK' if rec.get('ok') else 'FAIL'} | {rec.get('lower_s', '')} | "
            f"{rec.get('compile_s', '')} | "
            f"{ma.get('argument_size_in_bytes', 0)/1e9:.2f} | "
            f"{ma.get('temp_size_in_bytes', 0)/1e9:.2f} |")
    return "\n".join(lines)


def roofline_table(base_dir: str, opt_dir: str, mesh: str) -> str:
    base = rows_for(base_dir, mesh)
    opt = rows_for(opt_dir, mesh)
    lines = [
        "| arch | cell | bound | base C/M/X (s) | opt C/M/X (s) | "
        "dominant Δ | useful base→opt |",
        "|---|---|---|---|---|---|---|",
    ]
    for key in sorted(base):
        b = base[key]
        o = opt.get(key)
        fmt = lambda r: (f"{r['compute_s']:.2g}/{r['memory_s']:.2g}/"
                         f"{r['collective_s']:.2g}")
        dom_b = max(b["compute_s"], b["memory_s"], b["collective_s"])
        if o:
            dom_o = max(o["compute_s"], o["memory_s"], o["collective_s"])
            delta = f"{dom_b/dom_o:.2f}x" if dom_o else "-"
            useful = f"{b['useful_frac']:.2f}→{o['useful_frac']:.2f}"
            ofmt = fmt(o)
        else:
            delta, useful, ofmt = "-", f"{b['useful_frac']:.2f}", "-"
        lines.append(
            f"| {key[0]} | {key[1]} | {b['bound']} | {fmt(b)} | {ofmt} | "
            f"{delta} | {useful} |")
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--what", default="roofline",
                    choices=["roofline", "dryrun"])
    ap.add_argument("--base", default="dryrun")
    ap.add_argument("--opt", default="dryrun_opt")
    ap.add_argument("--mesh", default="pod_16x16")
    a = ap.parse_args()
    if a.what == "roofline":
        print(roofline_table(a.base, a.opt, a.mesh))
    else:
        print(dryrun_table(a.base))
