"""Training launcher: ``python -m repro.launch.train --arch <id> ...``

Runs the production train step on whatever devices exist (CPU dev loop, or a
real TPU slice where the same code path scales to the dry-run meshes). On
TPU, XLA latency-hiding flags below overlap FSDP all-gathers / gradient
reduce-scatters with compute — set before jax initializes.
"""
import argparse
import os

TPU_PERF_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_overlap_compute_collective_tc=true "
)
if os.environ.get("REPRO_TPU_FLAGS", "0") == "1":
    os.environ["XLA_FLAGS"] = TPU_PERF_FLAGS + os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402

from repro.configs import ARCH_IDS, get_config, get_smoke_config  # noqa: E402
from repro.data.synthetic import DataConfig  # noqa: E402
from repro.dist.sharding import axis_rules  # noqa: E402
from repro.launch.mesh import make_local_mesh  # noqa: E402
from repro.optim.adamw import AdamWConfig  # noqa: E402
from repro.train.train_step import TrainConfig  # noqa: E402
from repro.train.trainer import LoopConfig, train_loop  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--grad-sync", default="entangle",
                    choices=["spmd", "entangle", "checksum"])
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(
        adamw=AdamWConfig(lr=1e-3, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps),
        grad_sync=args.grad_sync,
        grad_accum=args.grad_accum,
        max_seq=args.seq,
    )
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      batch_size=args.batch)
    loop = LoopConfig(total_steps=args.steps,
                      ckpt_every=max(args.steps // 4, 1),
                      ckpt_dir=args.ckpt_dir,
                      log_every=max(args.steps // 10, 1))
    mesh = make_local_mesh()
    print(f"[launch.train] arch={cfg.name} devices={len(jax.devices())} "
          f"mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"grad_sync={args.grad_sync}")
    with mesh, axis_rules(mesh):
        state, losses = train_loop(cfg, tcfg, dcfg, loop)
    print(f"[launch.train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
