"""Training driver: checkpoint/restart, straggler roll-forward, elastic.

The loop composes the substrates:
  data (stateless synthetic pipeline + prefetch) -> jitted train_step ->
  NE/checksum-protected gradient sync -> async checkpointing -> restart.

Failure drills (exercised in tests/examples):
  * kill/restart: trainer resumes bit-exact from the latest atomic snapshot
    (data pipeline is pure-in-step, so no data state to restore);
  * straggler: a deadline-missed gradient block is rolled forward from the
    other M-1 entangled blocks (loss curve provably unaffected);
  * elastic: restore() re-shards the state onto a different mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.synthetic import DataConfig, SyntheticLM
from repro.train.checkpoint import CheckpointManager
from repro.train.train_step import TrainConfig, init_state, make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 25
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    seed: int = 0
    fail_block_at_step: Optional[int] = None  # inject fail-stop at this step


def train_loop(cfg: ModelConfig, tcfg: TrainConfig, dcfg: DataConfig,
               loop: LoopConfig, log: Callable[[str], None] = print):
    # the LR schedule is defined over the run: a loop shorter than the
    # configured warmup would otherwise train at ~0 lr for its whole life
    # (smoke runs, short fine-tunes)
    if tcfg.adamw.total_steps > loop.total_steps:
        tcfg = dataclasses.replace(
            tcfg,
            adamw=dataclasses.replace(
                tcfg.adamw,
                total_steps=loop.total_steps,
                warmup_steps=min(tcfg.adamw.warmup_steps,
                                 max(loop.total_steps // 10, 1)),
            ),
        )

    data = SyntheticLM(dcfg)
    ckpt = CheckpointManager(loop.ckpt_dir)
    key = jax.random.PRNGKey(loop.seed)

    state = init_state(key, cfg, tcfg)
    start_step = 0
    if ckpt.latest_step() is not None:
        state, start_step = ckpt.restore(state)
        log(f"[trainer] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, tcfg))
    step_fail = None
    if loop.fail_block_at_step is not None and tcfg.grad_sync in ("entangle", "checksum"):
        step_fail = jax.jit(make_train_step(cfg, tcfg, failed_block=1))

    losses = []
    t0 = time.monotonic()
    for step in range(start_step, loop.total_steps):
        batch = {k: jax.numpy.asarray(v) for k, v in data.batch(step).items()}
        fn = step_fail if (step_fail is not None and step == loop.fail_block_at_step) else step_fn
        state, metrics = fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % loop.log_every == 0:
            dt = time.monotonic() - t0
            log(f"[trainer] step {step+1} loss={losses[-1]:.4f} "
                f"gnorm={float(metrics['grad_norm']):.3f} ({dt:.1f}s)")
        if (step + 1) % loop.ckpt_every == 0:
            ckpt.save(state, step + 1)
    ckpt.save(state, loop.total_steps, blocking=True)
    return state, np.array(losses)
