"""Straggler mitigation: deadline-miss == fail-stop (paper Sec. I).

The paper motivates fail-stop recovery with cores that "do not return the
results within a predetermined deadline". DeadlineExecutor runs per-stream
host callables under a wall-clock deadline; a miss marks that stream failed
and the caller rolls FORWARD via disentanglement of the other M-1 streams —
no waiting, no recomputation (contrast: checkpoint-rollback would waste all
M streams' work; plain recomputation doubles latency).
"""
from __future__ import annotations

import concurrent.futures as cf
import dataclasses
import time
from typing import Callable, Optional, Sequence


@dataclasses.dataclass
class StreamResult:
    index: int
    value: object = None
    failed: bool = False
    elapsed_s: float = 0.0


class DeadlineExecutor:
    def __init__(self, deadline_s: float, max_workers: Optional[int] = None):
        self.deadline_s = deadline_s
        self.max_workers = max_workers

    def run(self, fns: Sequence[Callable[[], object]]) -> list[StreamResult]:
        """Run stream computations concurrently; mark deadline misses failed.

        At most ONE failure is surfaced (the single-fail-stop model); if
        several streams miss the deadline, the slowest is marked failed and
        the rest are awaited (matching the paper's recovery guarantee)."""
        results = [StreamResult(i) for i in range(len(fns))]
        start = time.monotonic()
        with cf.ThreadPoolExecutor(max_workers=self.max_workers or len(fns)) as ex:
            futs = {ex.submit(fn): i for i, fn in enumerate(fns)}
            remaining = set(futs)
            deadline = start + self.deadline_s
            done, pending = cf.wait(remaining, timeout=max(deadline - time.monotonic(), 0))
            for f in done:
                i = futs[f]
                results[i].value = f.result()
                results[i].elapsed_s = time.monotonic() - start
            if pending:
                # single-failure budget: fail the one straggler, await others
                slowest = next(iter(pending))
                for f in pending:
                    if f is not slowest:
                        i = futs[f]
                        results[i].value = f.result()
                        results[i].elapsed_s = time.monotonic() - start
                i = futs[slowest]
                results[i].failed = True
                slowest.cancel()
        return results

    @staticmethod
    def failed_index(results: list[StreamResult]) -> Optional[int]:
        for r in results:
            if r.failed:
                return r.index
        return None
