"""The jitted training step: forward + CE loss (+MTP) + backward + AdamW.

Two gradient-sync flavors:
  * 'spmd'  — gradients reduced implicitly by GSPMD (pjit); the production
    path for the dry-run cells.
  * 'entangle'/'checksum' — explicit fault-tolerant sync through
    repro.dist.collectives (the paper's technique on the DP gradient path);
    used by the FT trainer/examples, where a deadline-missed shard is rolled
    forward from the surviving M-1 entangled blocks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.api import get_model, lm_loss
from repro.optim import adamw as adamw_mod
from repro.optim.adamw import AdamWConfig


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    grad_sync: str = "spmd"  # spmd | entangle | checksum
    grad_codec: str = "xla"  # xla | pallas — entangle/disentangle impl used
    #   by the FT sync ('pallas' routes through the fused kernel layer;
    #   'xla' is the jnp codec, fastest off-TPU and under shard_map)
    ft_M: int = 4
    max_seq: int = 4096
    grad_accum: int = 1  # microbatches per step (activation-memory lever:
    #   remat-saved layer inputs scale with the microbatch, not the batch)


def init_state(key, cfg: ModelConfig, tcfg: TrainConfig):
    model = get_model(cfg)
    params = model.init(key, cfg, max_seq=tcfg.max_seq)
    opt = adamw_mod.init(params, tcfg.adamw)
    return {"params": params, "opt": opt, "step": jnp.zeros((), jnp.int32)}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, *,
                    failed_block: Optional[int] = None):
    """Returns step(state, batch) -> (state, metrics). ``failed_block``
    statically injects a fail-stop into the FT grad sync (tests/examples)."""
    model = get_model(cfg)

    def step(state, batch):
        def loss_fn(params, b):
            logits = model.forward_train(params, b, cfg)
            return lm_loss(logits, b, cfg)

        if tcfg.grad_accum > 1:
            k = tcfg.grad_accum
            mb = jax.tree.map(
                lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)

            def acc_body(carry, b):
                loss_acc, g_acc = carry
                l, g = jax.value_and_grad(loss_fn)(state["params"], b)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                return (loss_acc + l, g_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.float32(0), zeros), mb)
            loss = loss / k
            grads = jax.tree.map(lambda g: g / k, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)

        diag: dict[str, Any] = {}
        if tcfg.grad_sync == "entangle":
            from repro.dist.collectives import ft_grad_sync

            grads, diag = ft_grad_sync(
                grads, axis_name=None, n_replicas=1, M=tcfg.ft_M,
                failed_block=failed_block, codec=tcfg.grad_codec)
        elif tcfg.grad_sync == "checksum":
            from repro.dist.collectives import checksum_grad_sync

            grads, diag = checksum_grad_sync(
                grads, axis_name=None, n_replicas=1, M=tcfg.ft_M,
                failed_block=failed_block)

        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        params, opt = adamw_mod.update(
            grads, state["opt"], state["params"], state["step"],
            adamw_mod.effective_lr_config(tcfg.adamw, cfg.d_model))
        new_state = {"params": params, "opt": opt, "step": state["step"] + 1}
        metrics = {"loss": loss, "grad_norm": gnorm, **diag}
        return new_state, metrics

    return step
