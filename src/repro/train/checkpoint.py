"""Fault-tolerant checkpointing: atomic, async, hash-verified, elastic.

  * atomic: written to ``step_N.tmp-<pid>`` then os.rename'd — a crash
    mid-write can never corrupt the latest checkpoint;
  * async: the device->host gather happens on the caller thread (cheap), the
    file I/O on a background thread, off the training critical path;
  * verified: manifest stores per-leaf SHA-256; restore refuses silent
    corruption (complements the paper's SDC story at the storage layer);
  * elastic: restore() takes target NamedShardings — a checkpoint written on
    a 512-chip mesh restores onto 256 or 1024 chips (or 1 CPU) by
    device_put against the new sharding: checkpoint-level re-sharding is the
    elastic-scaling path after a pod loss.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _tree_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------ save ----

    def save(self, state, step: int, blocking: bool = False):
        """Snapshot to host memory synchronously, write files async."""
        host = jax.tree.map(lambda x: np.asarray(x), state)
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(host, int(step)), daemon=True
        )
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, host_state, step: int):
        tmp = self.dir / f"step_{step:08d}.tmp-{os.getpid()}"
        final = self.dir / f"step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        manifest = {"step": step, "leaves": []}
        for i, (path, leaf) in enumerate(_tree_paths(host_state)):
            fn = f"leaf_{i:05d}.npy"
            np.save(tmp / fn, leaf)
            digest = hashlib.sha256((tmp / fn).read_bytes()).hexdigest()
            manifest["leaves"].append(
                {"path": path, "file": fn, "sha256": digest,
                 "shape": list(np.shape(leaf)), "dtype": str(np.asarray(leaf).dtype)}
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------- restore ----

    def all_steps(self) -> list[int]:
        return sorted(
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if p.is_dir() and not p.name.endswith(tuple(f"tmp-{s}" for s in [""]))
            and ".tmp-" not in p.name
        )

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_like, step: Optional[int] = None, shardings=None):
        """Load into the structure of ``state_like``; device_put each leaf
        against ``shardings`` (same treedef) if given — the elastic path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_path = {e["path"]: e for e in manifest["leaves"]}

        leaves_kp, treedef = jax.tree_util.tree_flatten_with_path(state_like)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None
            else [None] * len(leaves_kp)
        )
        out = []
        for (kp, like), shd in zip(leaves_kp, shard_leaves):
            entry = by_path[jax.tree_util.keystr(kp)]
            raw = (d / entry["file"]).read_bytes()
            if hashlib.sha256(raw).hexdigest() != entry["sha256"]:
                raise IOError(
                    f"checkpoint corruption detected in {entry['file']} "
                    f"(sha mismatch) — refusing to load")
            arr = np.load(d / entry["file"])
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), step
