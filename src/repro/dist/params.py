"""Sharding assignment for parameter / batch / cache pytrees.

The dry-run compiles every (arch x shape-cell) against ShapeDtypeStruct
specs; these helpers map each leaf to a :class:`NamedSharding` on the
production mesh. The policy is deliberately structural (no per-model
tables): tensor-parallel ("model") on the largest divisible weight axis,
data-parallel ("data", plus "pod" when present) on the leading batch axis
of inputs and caches, replicate whatever does not divide.
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _model_extent(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def _param_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    ext = _model_extent(mesh)
    if ext > 1 and len(shape) >= 1:
        # shard the largest divisible axis on "model"; prefer trailing axes
        # on ties (output-feature sharding keeps matmul reduction local)
        order = sorted(range(len(shape)), key=lambda i: (shape[i], i),
                       reverse=True)
        for i in order:
            if shape[i] >= ext and shape[i] % ext == 0:
                entries: list[Any] = [None] * len(shape)
                entries[i] = "model"
                return P(*entries)
    return P()


def _batch_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    axes = _data_axes(mesh)
    ext = math.prod(mesh.shape[a] for a in axes) if axes else 1
    if axes and len(shape) >= 1 and shape[0] % ext == 0 and shape[0] >= ext:
        return P(axes if len(axes) > 1 else axes[0])
    return P()


def param_shardings(cfg, mesh: Mesh, p_specs) -> Any:
    """NamedSharding tree matching ``p_specs`` (model/tensor parallel)."""
    del cfg  # policy is structural; cfg kept for future per-arch overrides
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _param_spec(tuple(s.shape), mesh)),
        p_specs,
    )


def batch_shardings(mesh: Mesh, batch_specs) -> Any:
    """Shard the leading (global-batch) axis over the data axes."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _batch_spec(tuple(s.shape), mesh)),
        batch_specs,
    )


def cache_shardings(cfg, mesh: Mesh, cache_specs) -> Any:
    """KV/conv/SSM caches: batch-major leaves shard like batches."""
    del cfg
    return jax.tree.map(
        lambda s: NamedSharding(mesh, _batch_spec(tuple(s.shape), mesh)),
        cache_specs,
    )
