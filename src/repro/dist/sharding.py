"""Logical-axis sharding: MaxText-style named-rule annotations.

Model code annotates activations with *logical* axis names
(``constrain(x, "batch", "seq", "embed")``); this module maps them to
*mesh* axes through an active rule table installed by :func:`axis_rules`.
Outside any ``axis_rules`` context ``constrain`` is the identity, so the
same model code runs unsharded in unit tests and sharded in the dry-run.

Rules (logical -> mesh axes):

  batch                    -> ("pod", "data")  (whichever exist in the mesh)
  experts / heads / kv_heads /
  mlp / vocab / embed_model -> ("model",)
  seq / embed / frames / None -> replicated

A mesh-axis assignment is dropped per-array when the dimension size is not
divisible by the mesh-axis extent (GSPMD requires divisibility); this keeps
``constrain`` total over every smoke/full shape without per-model casing.
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Optional, Sequence

import numpy as np

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()

# logical name -> candidate mesh axes (in order; all present ones are used)
_RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "experts": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "embed_model": ("model",),
    "seq": (),
    "embed": (),
    "frames": (),
}


def _current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def axis_rules(mesh: Mesh):
    """Install ``mesh`` as the target of logical-axis annotations."""
    prev = _current_mesh()
    _STATE.mesh = mesh
    try:
        yield mesh
    finally:
        _STATE.mesh = prev


def _mesh_axes_for(name: Optional[str], mesh: Mesh) -> tuple[str, ...]:
    if name is None:
        return ()
    cands = _RULES.get(name, ())
    return tuple(a for a in cands if a in mesh.shape)


def _extent(axes: Sequence[str], mesh: Mesh) -> int:
    return math.prod(mesh.shape[a] for a in axes) if axes else 1


def logical_to_spec(
    logical: Sequence[Optional[str]],
    mesh: Mesh,
    shape: Optional[Sequence[int]] = None,
) -> P:
    """PartitionSpec for logical axis names, dropping non-divisible axes."""
    entries = []
    for i, name in enumerate(logical):
        axes = _mesh_axes_for(name, mesh)
        if shape is not None and axes and shape[i] % _extent(axes, mesh):
            axes = ()
        if not axes:
            entries.append(None)
        elif len(axes) == 1:
            entries.append(axes[0])
        else:
            entries.append(tuple(axes))
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate ``x`` with the sharding implied by the logical axis names.

    Identity when no :func:`axis_rules` context is active (unit tests) or
    when the mesh is trivial.
    """
    mesh = _current_mesh()
    if mesh is None or math.prod(mesh.shape.values()) == 1:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    spec = logical_to_spec(logical, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def serve_mesh(min_devices: int = 2) -> Optional[Mesh]:
    """Mesh for the batched serving engine: all local devices on one
    ``data`` axis.

    Returns None on a single device (the engine runs unsharded — the common
    CPU/test case). With devices > 1 the engine traces its decode step and
    head GEMM under ``axis_rules(serve_mesh())``, so every ``batch``-tagged
    activation — including the slot batch feeding the entangled head GEMM —
    shards across devices; the entanglement groups stay device-local because
    the group axis is folded out of the batch before the kernel call.
    """
    n = jax.device_count()
    if n < min_devices:
        return None
    return Mesh(np.asarray(jax.devices()), ("data",))


def axis_extent(name: str) -> int:
    """Number of shards the logical axis ``name`` is split into (1 when no
    rule context is active)."""
    mesh = _current_mesh()
    if mesh is None:
        return 1
    return _extent(_mesh_axes_for(name, mesh), mesh)
