"""Fault-tolerant gradient synchronization — the paper's codec on the
data-parallel gradient path.

``ft_grad_sync`` protects the cross-replica gradient sum with numerical
entanglement: each gradient tensor is fixed-point quantized into the plan's
eq. (13) budget (with ``n_replicas`` reduction headroom), split into M
stream blocks, entangled, summed across replicas (the sum is an LSB op, so
it commutes with the entanglement operator E), and disentangled. A replica
or block that fail-stops (deadline miss, preemption) is rolled forward
exactly from the surviving M-1 entangled blocks — the training step is
bit-identical with and without the failure (tested).

``checksum_grad_sync`` is the checksum-ABFT baseline (paper Sec. II) on the
same path: one extra sum stream, float arithmetic, recovery by subtraction.

Codec dispatch: ``codec='xla'`` runs the jnp reference codec (fastest under
XLA fusion on CPU/GPU; always valid under shard_map), ``codec='pallas'``
routes entangle/disentangle through the fused Pallas kernel layer
(:mod:`repro.kernels.ops`) — the TPU production path.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.core.entangle import disentangle as _disentangle_xla
from repro.core.entangle import entangle as _entangle_xla
from repro.core.failstop import GARBAGE
from repro.core.plan import EntanglePlan, make_plan


def _pow2_scale(amax: jax.Array, max_magnitude: int, depth: int) -> jax.Array:
    """Power-of-two fixed-point scale with ``depth``-term sum headroom.

    Same policy as :func:`repro.core.fixed_point.fit_scale` but takes the
    (possibly cross-replica) amax explicitly so all replicas agree on it.
    """
    budget = jnp.float32(max_magnitude // max(depth, 1))
    amax = jnp.maximum(amax.astype(jnp.float32), jnp.finfo(jnp.float32).tiny)
    return jnp.exp2(jnp.floor(jnp.log2(budget / amax)))


def _to_blocks(flat: jax.Array, M: int) -> tuple[jax.Array, int]:
    n = flat.shape[0]
    pad = (-n) % M
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(M, (n + pad) // M), n


def _codec_fns(codec: str, plan: EntanglePlan, failed: Optional[int]):
    if codec == "pallas":
        from repro.kernels import ops as kops

        return (
            lambda q: kops.entangle(q, plan),
            lambda eps: kops.disentangle(eps, plan, failed=failed),
        )
    return (
        lambda q: _entangle_xla(q, plan),
        lambda eps: _disentangle_xla(eps, plan, failed=failed),
    )


def ft_grad_sync(
    grads: Any,
    *,
    axis_name: Optional[str],
    n_replicas: int,
    M: int = 4,
    failed_block: Optional[int] = None,
    plan: Optional[EntanglePlan] = None,
    codec: str = "xla",
) -> tuple[Any, dict]:
    """Entanglement-protected mean of ``grads`` across ``axis_name``.

    Args:
      grads: pytree of float gradient tensors (per-replica values inside
        shard_map; the full gradients when ``axis_name`` is None).
      axis_name: mapped axis to psum over, or None for single-process use.
      n_replicas: number of contributions to the sum (reduction headroom).
      M: number of entangled stream blocks per tensor.
      failed_block: statically-known fail-stopped block index; its entangled
        data is replaced with poison to prove recovery never reads it.
      plan: entanglement plan override (default ``make_plan(M, 32)``).
      codec: 'xla' (jnp codec) or 'pallas' (fused kernel layer).

    Returns:
      (synced gradient pytree, diagnostics dict).
    """
    plan = plan or make_plan(M, 32)
    entangle_fn, disentangle_fn = _codec_fns(codec, plan, failed_block)

    def sync_leaf(g: jax.Array) -> jax.Array:
        blocks, n = _to_blocks(g.reshape(-1).astype(jnp.float32), M)
        amax = jnp.max(jnp.abs(blocks))
        if axis_name is not None:
            amax = jax.lax.pmax(amax, axis_name)
        scale = _pow2_scale(amax, plan.max_output_magnitude, n_replicas)
        q = jnp.round(blocks * scale).astype(jnp.int32)
        eps = entangle_fn(q)
        if axis_name is not None:
            eps = jax.lax.psum(eps, axis_name)
        if failed_block is not None:
            eps = eps.at[failed_block % M].set(GARBAGE)
        rec = disentangle_fn(eps)
        out = rec.astype(jnp.float32) / (scale * n_replicas)
        return out.reshape(-1)[:n].reshape(g.shape).astype(g.dtype)

    synced = jax.tree.map(sync_leaf, grads)
    diag = {
        "ne_failed": -1 if failed_block is None else failed_block % M,
        "ne_M": M,
    }
    return synced, diag


def checksum_grad_sync(
    grads: Any,
    *,
    axis_name: Optional[str],
    n_replicas: int,
    M: int = 4,
    failed_block: Optional[int] = None,
) -> tuple[Any, dict]:
    """Checksum-ABFT baseline: one extra sum stream, float recovery."""

    def sync_leaf(g: jax.Array) -> jax.Array:
        blocks, n = _to_blocks(g.reshape(-1).astype(jnp.float32), M)
        csum = jnp.sum(blocks, axis=0)
        if axis_name is not None:
            blocks = jax.lax.psum(blocks, axis_name)
            csum = jax.lax.psum(csum, axis_name)
        if failed_block is not None:
            fb = failed_block % M
            others = jnp.sum(blocks, axis=0) - blocks[fb]
            blocks = blocks.at[fb].set(csum - others)
        out = blocks / n_replicas
        return out.reshape(-1)[:n].reshape(g.shape).astype(g.dtype)

    synced = jax.tree.map(sync_leaf, grads)
    diag = {"cs_failed": -1 if failed_block is None else failed_block % M}
    return synced, diag
