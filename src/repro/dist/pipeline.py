"""Pipeline parallelism: GPipe-style rotational schedule via shard_map.

Each mesh position along the pipeline axis owns one stage (a contiguous
slice of layers). Microbatches enter at stage 0; every tick each stage
applies its layers and ppermutes its activation to the successor; the last
stage collects finished microbatches. ``N + S - 1`` ticks drain N
microbatches through S stages — the standard fill/steady/drain schedule.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def make_layer_stage(layer_fn: Callable) -> Callable:
    """Lift a per-layer fn ``layer_fn(params_i, x) -> x`` into a stage fn
    applying a stacked slice of layers sequentially (scanned)."""

    def stage_fn(stage_params: Any, x: jax.Array) -> jax.Array:
        def body(carry, p):
            return layer_fn(p, carry), None

        out, _ = jax.lax.scan(body, x, stage_params)
        return out

    return stage_fn


def split_stages(layer_params: Any, n_stages: int) -> Any:
    """Reshape layer-stacked params [L, ...] -> [S, L/S, ...]."""

    def split(p):
        L = p.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return p.reshape(n_stages, L // n_stages, *p.shape[1:])

    return jax.tree.map(split, layer_params)


def pipeline_stack(
    stage_fn: Callable,
    stage_params: Any,
    x_micro: jax.Array,
    *,
    mesh: Mesh,
    axis: str,
) -> jax.Array:
    """Run ``x_micro`` [N_micro, ...] through S pipeline stages.

    ``stage_params`` leaves are stage-stacked [S, ...]; stage s lives on
    mesh position s of ``axis``. Returns outputs [N_micro, ...] equal to
    applying all stages sequentially.
    """
    S = mesh.shape[axis]
    N = x_micro.shape[0]
    shift_perm = [(i, (i + 1) % S) for i in range(S)]

    def spmd(params, xs):
        params = jax.tree.map(lambda p: p[0], params)  # local stage slice
        idx = jax.lax.axis_index(axis)
        carry = jnp.zeros(xs.shape[1:], xs.dtype)
        outs = jnp.zeros(xs.shape, xs.dtype)

        def tick(t, state):
            carry, outs = state
            x_in = xs[jnp.minimum(t, N - 1)]
            y = stage_fn(params, jnp.where(idx == 0, x_in, carry))
            out_t = jnp.clip(t - (S - 1), 0, N - 1)
            emit = (idx == S - 1) & (t >= S - 1)
            placed = jax.lax.dynamic_update_slice(
                outs, y[None], (out_t,) + (0,) * (outs.ndim - 1)
            )
            outs = jnp.where(emit, placed, outs)
            carry = jax.lax.ppermute(y, axis, shift_perm)
            return carry, outs

        _, outs = jax.lax.fori_loop(0, N + S - 1, tick, (carry, outs))
        return outs[None]  # [1, N, ...]; valid on the last stage

    result = shard_map(
        spmd,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(axis),
        check_rep=False,
    )(stage_params, x_micro)
    return result[-1]
