"""Distribution layer: logical-axis sharding rules, parameter/batch/cache
sharding assignment, fault-tolerant gradient collectives (the paper's
numerical entanglement on the data-parallel gradient path) and pipeline
parallelism.

Kept import-light: importing :mod:`repro.dist` must never touch jax device
state (the dry-run sets XLA device-count flags before first jax init).
"""
