"""Attention core: memory-efficient (flash-style) attention in pure JAX.

Materialized [T, S] score tensors are impossible at the assigned shapes
(prefill_32k: 32768^2 f32 scores ~ 4 GiB per head-batch), so train/prefill
attention runs as a two-level lax.scan with online softmax over KV blocks —
O(qb * kb) live scores. A custom VJP recomputes blocks in backward (the
standard flash backward), so autodiff never materializes full scores either.

Heads layout is GQA-grouped: q [B, Hkv, G, T, dk], k [B, Hkv, S, dk],
v [B, Hkv, S, dv]; MQA/MHA are G=H / G=1 special cases; MLA folds its
nope+rope parts into dk and uses dv != dk.

Masking: static descriptor (kind, window); absolute positions derive from
block indices. Causal blocks above the diagonal are *masked, not skipped*
(XLA scans have static trip counts): a known 2x FLOP overhead on the causal
flash path, recorded as a §Perf hillclimb item (block-skipping Pallas flash).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

NEG = -1e30


class MaskInfo(NamedTuple):
    kind: str  # causal | window | full
    window: int = 0
    kv_len: int = 0  # true (unpadded) kv length
    q_off: int = 0  # absolute position of query 0 (chunked prefill at offset)


def _block_mask(info: MaskInfo, qpos, kpos):
    """Boolean [qb, kb] mask from absolute positions."""
    ok = kpos[None, :] < info.kv_len if info.kv_len else None
    if info.kind == "full":
        return ok if ok is not None else None
    causal = kpos[None, :] <= qpos[:, None]
    if info.kind == "window":
        causal &= kpos[None, :] > qpos[:, None] - info.window
    return causal if ok is None else (causal & ok)


def _pad_axis(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    w = [(0, 0)] * x.ndim
    w[axis] = (0, pad)
    return jnp.pad(x, w)


def _band(info: MaskInfo, iq: int, qb: int, kb: int, nk: int) -> tuple[int, int]:
    """Static kv-block range [lo, hi) that q-block iq can attend to.

    Causal: blocks 0..ceil((q_off + (iq+1)*qb)/kb). Window: additionally
    bounded below. Full: everything. Banding skips masked-out blocks
    ENTIRELY — the §Perf fix for the 2x causal / O(T/window) windowed flash
    waste. ``info.q_off`` shifts the band for chunked prefill at an offset."""
    if info.kind == "full":
        return 0, nk
    hi = min(nk, -(-(info.q_off + (iq + 1) * qb) // kb))
    if info.kind == "window":
        lo = max(0, (info.q_off + iq * qb - info.window + 1) // kb)
        return lo, hi
    return 0, hi


def _flash_fwd_inner(q, k, v, info: MaskInfo, scale, qb, kb):
    """q [B,Hkv,G,T,dk] (T % qb == 0), k/v padded to kb multiples.
    Returns out [B,Hkv,G,T,dv], lse [B,Hkv,G,T].

    Outer loop over q blocks is a PYTHON loop (static band bounds per
    block); inner loop a lax.scan over just that block's band."""
    B, Hkv, G, T, dk = q.shape
    S = k.shape[2]
    dv = v.shape[-1]
    nq, nk = T // qb, S // kb
    qs = q.reshape(B, Hkv, G, nq, qb, dk)
    ks = jnp.moveaxis(k.reshape(B, Hkv, nk, kb, dk), 2, 0)  # [nk, B,Hkv,kb,dk]
    vs = jnp.moveaxis(v.reshape(B, Hkv, nk, kb, dv), 2, 0)

    outs, lses = [], []
    for iq in range(nq):
        qi = qs[:, :, :, iq]
        qpos = info.q_off + iq * qb + jnp.arange(qb)
        lo, hi = _band(info, iq, qb, kb, nk)

        def kv_step(carry, kj_idx, _qi=qi, _qpos=qpos):
            m, l, acc = carry
            kj, vj, jk = kj_idx
            kpos = jk * kb + jnp.arange(kb)
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", _qi.astype(jnp.float32),
                kj.astype(jnp.float32)) * scale
            mask = _block_mask(info, _qpos, kpos)
            if mask is not None:
                s = jnp.where(mask[None, None, None], s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            if mask is not None:
                p = jnp.where(mask[None, None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vj.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, qb), NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qb), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, qb, dv), jnp.float32)
        (m, l, acc), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (ks[lo:hi], vs[lo:hi], jnp.arange(lo, hi)))
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
        lses.append(m + jnp.log(jnp.maximum(l, 1e-30)))

    out = jnp.stack(outs, axis=3).reshape(B, Hkv, G, T, dv)
    lse = jnp.stack(lses, axis=3).reshape(B, Hkv, G, T)
    return out, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, info: MaskInfo, scale: float, qb: int, kb: int):
    out, _ = _flash_fwd_inner(q, k, v, info, scale, qb, kb)
    return out


def _flash_fwd(q, k, v, info, scale, qb, kb):
    out, lse = _flash_fwd_inner(q, k, v, info, scale, qb, kb)
    return out, (q, k, v, out, lse)


def _flash_bwd(info, scale, qb, kb, res, dout):
    q, k, v, out, lse = res
    B, Hkv, G, T, dk = q.shape
    S = k.shape[2]
    dv = v.shape[-1]
    nq, nk = T // qb, S // kb
    dout = dout.astype(jnp.float32)
    D = jnp.sum(dout * out, axis=-1)  # [B,Hkv,G,T]

    qs = q.reshape(B, Hkv, G, nq, qb, dk)
    dos = dout.reshape(B, Hkv, G, nq, qb, dv)
    lses = lse.reshape(B, Hkv, G, nq, qb)
    Ds = D.reshape(B, Hkv, G, nq, qb)
    qs_s = jnp.moveaxis(qs, 3, 0)  # [nq, ...] for inner scans
    dos_s = jnp.moveaxis(dos, 3, 0)
    lses_s = jnp.moveaxis(lses, 3, 0)
    Ds_s = jnp.moveaxis(Ds, 3, 0)
    ks = jnp.moveaxis(k.reshape(B, Hkv, nk, kb, dk), 2, 0)
    vs = jnp.moveaxis(v.reshape(B, Hkv, nk, kb, dv), 2, 0)

    def p_block(qi, lse_i, qpos, kj, jk):
        kpos = jk * kb + jnp.arange(kb)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qi.astype(jnp.float32),
                       kj.astype(jnp.float32)) * scale
        mask = _block_mask(info, qpos, kpos)
        if mask is not None:
            s = jnp.where(mask[None, None, None], s, NEG)
        p = jnp.exp(s - lse_i[..., None])
        if mask is not None:
            p = jnp.where(mask[None, None, None], p, 0.0)
        return p

    # dq: python loop over q blocks, banded inner scan over kv blocks
    dq_blocks = []
    for iq in range(nq):
        qi, do_i = qs[:, :, :, iq], dos[:, :, :, iq]
        lse_i, D_i = lses[:, :, :, iq], Ds[:, :, :, iq]
        qpos = info.q_off + iq * qb + jnp.arange(qb)
        lo, hi = _band(info, iq, qb, kb, nk)

        def inner(dq_acc, ys, _qi=qi, _do=do_i, _lse=lse_i, _D=D_i, _qpos=qpos):
            kj, vj, jk = ys
            p = p_block(_qi, _lse, _qpos, kj, jk)
            dp = jnp.einsum("bhgqd,bhkd->bhgqk", _do, vj.astype(jnp.float32))
            ds = p * (dp - _D[..., None])
            dq_acc += jnp.einsum("bhgqk,bhkd->bhgqd", ds, kj.astype(jnp.float32))
            return dq_acc, None

        dq0 = jnp.zeros((B, Hkv, G, qb, dk), jnp.float32)
        dq_i, _ = lax.scan(inner, dq0, (ks[lo:hi], vs[lo:hi], jnp.arange(lo, hi)))
        dq_blocks.append(dq_i * scale)
    dq = jnp.stack(dq_blocks, axis=3).reshape(q.shape).astype(q.dtype)

    # dk/dv: python loop over kv blocks, banded inner scan over q blocks
    q_ranges = []
    for jk in range(nk):
        touch = [iq for iq in range(nq)
                 if _band(info, iq, qb, kb, nk)[0] <= jk < _band(info, iq, qb, kb, nk)[1]]
        q_ranges.append((touch[0], touch[-1] + 1) if touch else (0, 0))

    dk_blocks, dv_blocks = [], []
    for jk in range(nk):
        kj, vj = ks[jk], vs[jk]
        qlo, qhi = q_ranges[jk]
        z = (jnp.zeros((B, Hkv, kb, dk), jnp.float32),
             jnp.zeros((B, Hkv, kb, dv), jnp.float32))
        if qhi > qlo:
            def inner2(carry, ys, _kj=kj, _vj=vj, _jk=jk):
                dk_acc, dv_acc = carry
                qi, do_i, lse_i, D_i, iq = ys
                qpos = info.q_off + iq * qb + jnp.arange(qb)
                p = p_block(qi, lse_i, qpos, _kj, _jk)
                dv_acc += jnp.einsum("bhgqk,bhgqd->bhkd", p, do_i)
                dp = jnp.einsum("bhgqd,bhkd->bhgqk", do_i, _vj.astype(jnp.float32))
                ds = p * (dp - D_i[..., None])
                dk_acc += jnp.einsum("bhgqk,bhgqd->bhkd", ds,
                                     qi.astype(jnp.float32))
                return (dk_acc, dv_acc), None

            z, _ = lax.scan(
                inner2, z,
                (qs_s[qlo:qhi], dos_s[qlo:qhi], lses_s[qlo:qhi],
                 Ds_s[qlo:qhi], jnp.arange(qlo, qhi)))
        dk_blocks.append(z[0] * scale)
        dv_blocks.append(z[1])
    dk_ = jnp.stack(dk_blocks, axis=2).reshape(k.shape).astype(k.dtype)
    dv_ = jnp.stack(dv_blocks, axis=2).reshape(v.shape).astype(v.dtype)
    return dq, dk_, dv_


flash_attention.defvjp(_flash_fwd, _flash_bwd)

FLASH_THRESHOLD = 2048  # materialize below this T*S; flash above
DEFAULT_QB = 512
DEFAULT_KB = 512


def attend(q, k, v, *, kind: str, window: int = 0, kv_len: int = 0,
           scale: float | None = None, qb: int = DEFAULT_QB,
           kb: int = DEFAULT_KB, q_off: int = 0):
    """Dispatching attention: q [B,Hkv,G,T,dk], k [B,Hkv,S,dk],
    v [B,Hkv,S,dv] -> out [B,Hkv,G,T,dv] (f32).

    kind: causal | window | full. kv_len masks padded/unwritten tail keys.
    q_off is the absolute position of query 0 — chunked prefill attends a
    [B, C] chunk against a cache holding all earlier positions, so queries
    start at the chunk offset, not 0. Small problems take the materialized
    path (exact same math)."""
    B, Hkv, G, T, dk = q.shape
    S = k.shape[2]
    scale = scale or (1.0 / math.sqrt(dk))
    if T * S <= FLASH_THRESHOLD * FLASH_THRESHOLD // 4 or T == 1:
        qpos = jnp.arange(T) + q_off
        info = MaskInfo(kind, window, kv_len or 0, q_off)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        mask = _block_mask(info, qpos, jnp.arange(S))
        if mask is not None:
            s = jnp.where(mask[None, None, None], s, NEG)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    qp = _pad_axis(q, 3, qb)
    kp = _pad_axis(k, 2, kb)
    vp = _pad_axis(v, 2, kb)
    info = MaskInfo(kind, window, kv_len or S, q_off)
    out = flash_attention(qp, kp, vp, info, scale, qb, kb)
    return out[:, :, :, :T]


def _prefill_window_inner(q, k, v, qpos, kabs, window, scale):
    """Materialized abs-position-masked attention (one query band).

    ``qpos`` is [T] (one shared query offset — the bucketed chunk path) or
    [B, T] (per-row offsets — the token-packed path, where every row of
    the program is a DIFFERENT request at its own prefill offset).
    ``window == 0`` means plain causal (no lower bound)."""
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    ka = kabs[:, None, None, None, :]  # [B, 1, 1, 1, S]
    if qpos.ndim == 2:  # [B, T] per-row query positions
        qp = qpos[:, None, None, :, None]
    else:
        qp = qpos[None, None, None, :, None]  # [1, 1, 1, T, 1]
    ok = (ka >= 0) & (ka <= qp)
    if window:
        ok = ok & (ka > qp - window)
    s = jnp.where(ok, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))


def attend_prefill_window(q, k, v, *, qpos, kabs, window: int,
                          scale: float | None = None, qb: int = DEFAULT_QB):
    """Bucketed/chunked prefill attention for rolling-window layers.

    q [B,Hkv,G,T,dk] are the chunk's queries at absolute positions
    ``qpos`` [T] (consecutive); k/v [B,Hkv,S,*] concatenate the rolling
    cache (earlier chunks; S_c = S - T slots, slot order) with the chunk's
    own T keys IN POSITION ORDER (aligned with qpos — the caller contract
    that makes query banding possible), with per-row absolute key positions
    ``kabs`` [B, S] (-1 = invalid slot / padding past the row's prompt
    length). Each query attends to keys in its window (qpos - window, qpos]
    — the slot-order scrambling of the rolling buffer is undone by masking
    on absolute positions, exactly like :func:`attend_decode`.

    Large problems are processed in query bands of ``qb``: band [i0, i1)
    only needs the S_c cache slots plus chunk keys (i0 - window, i1), so
    live scores are O(qb * (S_c + qb + window)), never O(T * S) — the same
    banding idea as the flash path, without it the unchunked bucketed
    prefill of a production-scale window layer would OOM on scores."""
    scale = scale or (1.0 / math.sqrt(q.shape[-1]))
    T = q.shape[3]
    S = k.shape[2]
    if T * S <= FLASH_THRESHOLD * FLASH_THRESHOLD // 4:
        return _prefill_window_inner(q, k, v, qpos, kabs, window, scale)
    S_c = S - T  # leading rolling-cache slots
    outs = []
    for i0 in range(0, T, qb):
        i1 = min(i0 + qb, T)
        lo = S_c + max(0, i0 - window + 1)
        ks = jnp.concatenate([k[:, :, :S_c], k[:, :, lo : S_c + i1]], axis=2)
        vs = jnp.concatenate([v[:, :, :S_c], v[:, :, lo : S_c + i1]], axis=2)
        kab = jnp.concatenate([kabs[:, :S_c], kabs[:, lo : S_c + i1]], axis=1)
        outs.append(_prefill_window_inner(
            q[:, :, :, i0:i1], ks, vs, qpos[..., i0:i1], kab, window, scale))
    return jnp.concatenate(outs, axis=3)


def attend_prefill_packed(q, k, v, *, qpos, kabs=None,
                          scale: float | None = None, qb: int = DEFAULT_QB):
    """Per-row-offset causal prefill attention (token-packed serving).

    q [B,Hkv,G,T,dk] holds one chunk per row where every row belongs to a
    DIFFERENT request at its own prefill offset: row b's queries sit at
    absolute positions ``qpos[b]`` ([B, T], ``qpos[b, t] = off_b + t``).
    k/v [B,Hkv,S,*] are the row's FULL linear cache (all S slots, slot s
    holding absolute position s) with the chunk's keys already scattered in
    at ``qpos`` — so one fixed [B, T] program shape serves every mix of
    per-row offsets. ``kabs`` [B, S] overrides the slot->position map
    (default arange: the linear cache).

    Masking is per-row causal (kpos <= qpos). Keys past a row's written
    prefix are excluded by causality alone, and because masked scores
    underflow to exact 0.0 after softmax, attending over the full S slots
    is BITWISE identical to the per-batch chunked path's ``[:off+T]``
    slice (the PR 3 invariant that makes packed == chunked bit-identical).

    Large T is processed in query bands of ``qb`` (causal needs every
    earlier key, so only queries band — live scores stay O(qb * S))."""
    scale = scale or (1.0 / math.sqrt(q.shape[-1]))
    B = q.shape[0]
    T = q.shape[3]
    S = k.shape[2]
    if kabs is None:
        kabs = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    if T * S <= FLASH_THRESHOLD * FLASH_THRESHOLD // 4:
        return _prefill_window_inner(q, k, v, qpos, kabs, 0, scale)
    outs = []
    for i0 in range(0, T, qb):
        i1 = min(i0 + qb, T)
        outs.append(_prefill_window_inner(
            q[:, :, :, i0:i1], k, v, qpos[..., i0:i1], kabs, 0, scale))
    return jnp.concatenate(outs, axis=3)


def attend_decode(q, k, v, *, abs_pos, scale: float | None = None):
    """Single-position decode: q [B,Hkv,G,1,dk] against cache k/v [B,Hkv,S,*].
    abs_pos: [S] (shared positions) or [B, S] (per-row positions, the batched
    serving engine) absolute position of each cache slot (-1 = invalid) —
    covers both linear caches (arange) and rolling local-attention buffers.
    """
    scale = scale or (1.0 / math.sqrt(q.shape[-1]))
    s = jnp.einsum("bhgqd,bhkd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    ok = abs_pos >= 0
    if ok.ndim == 1:
        ok = ok[None, None, None, None, :]
    else:  # [B, S]: each batch row masks against its own positions
        ok = ok[:, None, None, None, :]
    s = jnp.where(ok, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
