"""Decoder assembly: pattern-unit scanned stacks + embedding/head + caches.

Every architecture's layer stack is expressed as repeating *pattern units*
(cfg.layer_pattern()), each unit a short tuple of block names. Homogeneous
units are stacked and driven by jax.lax.scan so the HLO contains each unit
body ONCE regardless of depth — a 61-layer DeepSeek-V3 compiles in the same
graph size as a 2-layer smoke model. Block registry:

  attn_dense  GQA/MQA (or MLA if cfg.mla) attention + dense MLP
  attn_moe    (MLA) attention + MoE FFN
  local_attn  sliding-window GQA attention + dense MLP
  mamba       Mamba-1 selective-SSM block (attn-free; no MLP)
  rglru       RG-LRU recurrent block + dense MLP
"""
from __future__ import annotations

import math

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L

Params = Any
Cache = Any


class Block(NamedTuple):
    init: Callable  # (key, cfg, max_seq) -> params
    apply: Callable  # (params, x, cfg, cache, pos, mode) -> (x, new_cache)
    init_cache: Optional[Callable]  # (cfg, batch, max_seq) -> cache or None


def _attn_then_mlp(attn_fn, mlp_fn):
    def apply(p, x, *, cfg, cache, pos, mode, lengths=None, ft=None):
        a, new_cache = attn_fn(p, x, cfg=cfg, cache=cache, pos=pos, mode=mode,
                               lengths=lengths, ft=ft)
        x = x + a
        x = x + mlp_fn(p, x, cfg=cfg, ft=ft)
        return x, new_cache

    return apply


# ---- block definitions ------------------------------------------------------

def _init_attn_dense(key, cfg, max_seq):
    k1, k2 = jax.random.split(key)
    if cfg.mla:
        p = {"attn": L.init_mla(k1, cfg, max_seq)}
    else:
        p = {"attn": L.init_attention(k1, cfg, max_seq)}
    p["mlp"] = L.init_mlp(k2, cfg, gated=cfg.norm_kind == "rmsnorm")
    return p


def _apply_attn_dense(p, x, *, cfg, cache, pos, mode, lengths=None, ft=None):
    if cfg.mla:
        a, nc = L.apply_mla(p["attn"], x, cfg=cfg, cache=cache, pos=pos,
                            mode=mode, lengths=lengths, ft=ft)
    else:
        a, nc = L.apply_attention(
            p["attn"], x, cfg=cfg, cache=cache, pos=pos, mode=mode,
            rope_theta=cfg.rope_theta if cfg.norm_kind == "rmsnorm" else None,
            lengths=lengths, ft=ft,
        )
    x = x + a
    x = x + L.apply_mlp(p["mlp"], x, cfg=cfg, ft=ft)
    return x, nc


def _init_attn_moe(key, cfg, max_seq):
    k1, k2 = jax.random.split(key)
    p = {"attn": L.init_mla(k1, cfg, max_seq) if cfg.mla else L.init_attention(k1, cfg, max_seq)}
    p["moe"] = L.init_moe(k2, cfg)
    return p


def _apply_attn_moe(p, x, *, cfg, cache, pos, mode, lengths=None, ft=None):
    if cfg.mla:
        a, nc = L.apply_mla(p["attn"], x, cfg=cfg, cache=cache, pos=pos,
                            mode=mode, lengths=lengths, ft=ft)
    else:
        a, nc = L.apply_attention(
            p["attn"], x, cfg=cfg, cache=cache, pos=pos, mode=mode,
            rope_theta=cfg.rope_theta, lengths=lengths, ft=ft,
        )
    x = x + a
    valid = (L._prefill_valid(L._prefill_off(pos, mode), x.shape[1], lengths)
             if mode == "prefill" else None)
    x = x + L.apply_moe(p["moe"], x, cfg=cfg, valid=valid, ft=ft)
    return x, nc


def _init_local_attn(key, cfg, max_seq):
    k1, k2 = jax.random.split(key)
    return {
        "attn": L.init_attention(k1, cfg, max_seq),
        "mlp": L.init_mlp(k2, cfg, gated=True),
    }


def _apply_local_attn(p, x, *, cfg, cache, pos, mode, lengths=None, ft=None):
    a, nc = L.apply_attention(
        p["attn"], x, cfg=cfg, cache=cache, pos=pos, mode=mode,
        window=cfg.local_window, rope_theta=cfg.rope_theta, lengths=lengths,
        ft=ft,
    )
    x = x + a
    x = x + L.apply_mlp(p["mlp"], x, cfg=cfg, ft=ft)
    return x, nc


def _apply_mamba(p, x, *, cfg, cache, pos, mode, lengths=None, ft=None):
    a, nc = L.apply_mamba(p, x, cfg=cfg, cache=cache, pos=pos, mode=mode,
                          lengths=lengths, ft=ft)
    return x + a, nc


def _init_rglru_block(key, cfg, max_seq):
    k1, k2 = jax.random.split(key)
    return {"rec": L.init_rglru(k1, cfg, max_seq), "mlp": L.init_mlp(k2, cfg, gated=True)}


def _apply_rglru_block(p, x, *, cfg, cache, pos, mode, lengths=None, ft=None):
    a, nc = L.apply_rglru(p["rec"], x, cfg=cfg, cache=cache, pos=pos,
                          mode=mode, lengths=lengths, ft=ft)
    x = x + a
    x = x + L.apply_mlp(p["mlp"], x, cfg=cfg, ft=ft)
    return x, nc


def _cache_attn(cfg, batch, max_seq):
    if cfg.mla:
        return L.init_mla_cache(cfg, batch, max_seq)
    return L.init_attn_cache(cfg, batch, max_seq)


BLOCKS: dict[str, Block] = {
    "attn_dense": Block(_init_attn_dense, _apply_attn_dense, _cache_attn),
    "attn_moe": Block(_init_attn_moe, _apply_attn_moe, _cache_attn),
    "local_attn": Block(
        _init_local_attn,
        _apply_local_attn,
        lambda cfg, b, s: L.init_attn_cache(cfg, b, s, window=cfg.local_window),
    ),
    "mamba": Block(L.init_mamba, _apply_mamba, L.init_mamba_cache),
    "rglru": Block(_init_rglru_block, _apply_rglru_block, L.init_rglru_cache),
}


# ---- stack assembly ---------------------------------------------------------

def init_stack(key, cfg: ModelConfig, max_seq: int):
    """Stacked params: list over pattern units; leaves have leading [repeat]."""
    units = []
    for blocks, repeat in cfg.layer_pattern():
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, repeat)

        def one(k, _blocks=blocks):
            ks = jax.random.split(k, len(_blocks))
            return tuple(
                BLOCKS[b].init(ks[i], cfg, max_seq) for i, b in enumerate(_blocks)
            )

        units.append(jax.vmap(one)(keys))
    return units


def init_stack_cache(cfg: ModelConfig, batch: int, max_seq: int):
    caches = []
    for blocks, repeat in cfg.layer_pattern():
        unit = tuple(BLOCKS[b].init_cache(cfg, batch, max_seq) for b in blocks)
        caches.append(
            jax.tree.map(lambda x: jnp.broadcast_to(x, (repeat,) + x.shape), unit)
        )
    return caches


def apply_stack(units_params, x, *, cfg: ModelConfig, caches=None, pos=None,
                mode="train", lengths=None, ft=None):
    """Run all pattern units; each unit is one lax.scan over its repeats.

    ``lengths`` [B] (bucketed batched prefill) carries per-row true prompt
    lengths down to every block so cache writes and recurrent state updates
    stay exact under bucket padding; ``pos`` in prefill mode is the static
    chunk offset, or a TRACED per-row int32 offset vector [B] on the
    token-packed path (every row a different request — one compiled shape
    for every packing mix). ``ft`` (serving) is the :class:`repro.ft.FTContext`
    protection context — the scan body traces each unit ONCE, so every
    repeat of a protected projection shares one compiled ProtectionPlan
    and one in-kernel roll-forward schedule; startup-quantized ``q8``
    weight stacks (repro.ft.prepare_params) are sliced per repeat by the
    scan exactly like the float masters, keeping per-layer int8 grids
    with zero in-trace quantization."""
    new_caches = []
    for u, (blocks, repeat) in enumerate(cfg.layer_pattern()):
        p_u = units_params[u]
        c_u = caches[u] if caches is not None else None

        def body(carry, xs, _blocks=blocks):
            h = carry
            if c_u is not None:
                p_i, c_i = xs
            else:
                p_i, c_i = xs, (None,) * len(_blocks)
            ncs = []
            for b, bname in enumerate(_blocks):
                h, nc = BLOCKS[bname].apply(
                    p_i[b], h, cfg=cfg, cache=c_i[b], pos=pos, mode=mode,
                    lengths=lengths, ft=ft,
                )
                ncs.append(nc if nc is not None else 0)
            return h, tuple(ncs)

        if mode == "train" and cfg.remat != "none":
            policy = (
                jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
                if cfg.remat == "dots"
                else jax.checkpoint_policies.nothing_saveable
            )
            body = jax.checkpoint(body, policy=policy)
        xs = (p_u, c_u) if c_u is not None else p_u
        x, ncs = lax.scan(body, x, xs)
        new_caches.append(ncs if mode in ("prefill", "decode") else None)
    return x, new_caches


# ---- embeddings / head ------------------------------------------------------

def init_embed(key, cfg: ModelConfig, max_seq: int):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "tok": L._he(k1, (cfg.vocab_size, cfg.d_model), cfg.d_model),
        "final_norm": L.init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = L._he(k2, (cfg.d_model, cfg.vocab_size), cfg.d_model)
    if cfg.norm_kind == "layernorm":  # whisper: learned positions
        p["pos"] = L._he(k3, (max_seq, cfg.d_model), cfg.d_model)
    return p


def embed_tokens(p, tokens, cfg: ModelConfig, pos=None):
    x = jnp.take(p["tok"], tokens, axis=0).astype(L.ACT_DTYPE)
    if "pos" in p:
        T = tokens.shape[1]
        if pos is None:
            x = x + p["pos"][:T][None].astype(L.ACT_DTYPE)
        elif jnp.ndim(pos) == 1:  # per-row positions, batched decode (T == 1)
            x = x + jnp.take(p["pos"], pos, axis=0)[:, None].astype(L.ACT_DTYPE)
        elif jnp.ndim(pos) == 2:  # [B, T] grid — token-packed prefill
            x = x + jnp.take(p["pos"], pos, axis=0).astype(L.ACT_DTYPE)
        else:
            x = x + lax.dynamic_slice_in_dim(p["pos"], pos, T, 0)[None].astype(L.ACT_DTYPE)
    return constrain(x, "batch", "seq", "embed")


def final_hidden(p, x, cfg: ModelConfig):
    """Final-norm'd hidden states — the input the FT-protected serving head
    (repro.ft.heads) quantizes; ``logits_head`` is head_project of this."""
    return L.apply_norm(p["final_norm"], x, cfg)


def readout_scale(cfg: ModelConfig) -> float:
    """muP-style readout temperature: post-norm h has unit RMS per dim, so
    1/sqrt(fan_in)-init weights give unit-variance logits and an initial
    CE of ln(V) + ~0.5; the extra 1/sqrt(d) starts training at the
    uniform-distribution loss instead (identical argmax ordering). Shared
    with the FT serving head so ft and plain logits stay on one scale."""
    return 1.0 / math.sqrt(cfg.d_model)


def head_project(p, h, cfg: ModelConfig):
    """Project final-norm'd hidden states to vocab logits."""
    w = p["tok"].T if cfg.tie_embeddings else p["head"]
    logits = jnp.einsum("btd,dv->btv", h, w.astype(L.ACT_DTYPE))
    logits = logits * readout_scale(cfg)
    return constrain(logits.astype(jnp.float32), "batch", "seq", "vocab")


def logits_head(p, x, cfg: ModelConfig):
    return head_project(p, final_hidden(p, x, cfg), cfg)
