"""Model-zoo building blocks, pure-functional JAX.

Conventions:
  * params are nested dicts of jnp arrays (f32 masters; matmuls run bf16),
  * every block has ``init(key, cfg, max_seq) -> params`` and
    ``apply(params, x, *, cfg, cache, pos, mode) -> (y, new_cache)``,
  * ``mode`` in {train, prefill, decode}; decode processes T=1 with a cache,
  * activations carry logical sharding annotations (repro.dist.sharding),
  * blocks are scanned over layers by the assemblers (models/transformer.py),
    so shapes/dtypes must be layer-invariant within a pattern unit.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain

ACT_DTYPE = jnp.bfloat16


def _he(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = fan_in or shape[0]
    return (jax.random.normal(key, shape) * (1.0 / math.sqrt(fan_in))).astype(dtype)


def _split(key, n):
    return list(jax.random.split(key, n))


# ----------------------------------------------------------------- norms ----

def init_norm(cfg: ModelConfig, d: int):
    if cfg.norm_kind == "layernorm":
        return {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))}
    return {"scale": jnp.ones((d,))}


def apply_norm(p, x, cfg: ModelConfig):
    x32 = x.astype(jnp.float32)
    if cfg.norm_kind == "layernorm":
        mu = jnp.mean(x32, -1, keepdims=True)
        var = jnp.var(x32, -1, keepdims=True)
        y = (x32 - mu) * lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"] + p["bias"]
        return y.astype(ACT_DTYPE)
    ms = jnp.mean(jnp.square(x32), -1, keepdims=True)  # f32 reduce (fused)
    if cfg.norm_f32:
        y = x32 * lax.rsqrt(ms + cfg.norm_eps) * p["scale"]
        return y.astype(ACT_DTYPE)
    # bf16 elementwise apply: no f32 [B,T,D] materialization (§Perf)
    inv = lax.rsqrt(ms + cfg.norm_eps).astype(ACT_DTYPE)
    return x.astype(ACT_DTYPE) * inv * p["scale"].astype(ACT_DTYPE)


# ------------------------------------------------------------------ rope ----

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """NeoX-style rotary embedding. x: [B, T, H, hd], positions: [B, T]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # [B, T, half]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ----------------------------------------------------------------- dense ----

def init_dense(key, d_in, d_out, bias=False):
    p = {"w": _he(key, (d_in, d_out))}
    if bias:
        p["b"] = jnp.zeros((d_out,))
    return p


def _dense_w(p):
    """The weight :func:`dense` would hand the protected path: the startup
    pre-quantized (wq, scale) pair when installed, else the float master."""
    return (p["q8"]["w"], p["q8"]["scale"]) if "q8" in p else p["w"]


def dense(p, x, *, ft=None, site=None):
    """Dense projection — THE protected-GEMM chokepoint.

    When an :class:`repro.ft.FTContext` is threaded down (serving, with
    ``ft_scope`` covering ``site``'s category) the matmul runs as the fused
    entangled int8 GEMM with in-kernel fail-stop roll-forward instead of
    the bf16 einsum; the bias stays in float either way. ``ft=None`` (train
    and every pre-existing caller) is the unprotected fast path.

    A ``q8`` entry (installed by :func:`repro.ft.prepare_params` at engine
    startup) carries the site's pre-quantized int8 weights + scale; when
    present the protected path uses it directly, so the traced step holds
    no eq.-13 weight-quantization ops — the float master ``w`` stays the
    source of truth for every unprotected caller.
    """
    if ft is not None and site is not None and ft.protects(site):
        y = ft.matmul(site, x, _dense_w(p)).astype(ACT_DTYPE)
    else:
        y = jnp.einsum("...d,df->...f", x.astype(ACT_DTYPE),
                       p["w"].astype(ACT_DTYPE))
    if "b" in p:
        y = y + p["b"].astype(ACT_DTYPE)
    return y


def dense_fanout(ps, x, *, ft, sites):
    """Fanout form of :func:`dense`: every site in ``sites`` projects the
    SAME activations ``x`` — attention Q/K/V, MLP gate/up, RG-LRU
    in_gate/in_x, MLA's two ``h`` projections, the MoE shared expert.

    When all sites are protected the group runs through
    :meth:`repro.ft.FTContext.matmul_fanout`: one quantize + group-permute
    codec pass feeds every member's fused entangled kernel call
    (bit-identical to per-site :func:`dense` calls, tested), and the
    engine's census-only traces mark the group as chainable at
    plan-compile time. Any other case — no ``ft``, a site out of scope —
    degrades to the per-site path. Returns one output per site, in order.
    """
    if ft is None or not all(ft.protects(s) for s in sites):
        return [dense(p, x, ft=ft, site=s) for p, s in zip(ps, sites)]
    ys = ft.matmul_fanout(tuple(sites), x,
                          tuple(_dense_w(p) for p in ps))
    outs = []
    for p, y in zip(ps, ys):
        y = y.astype(ACT_DTYPE)
        if "b" in p:
            y = y + p["b"].astype(ACT_DTYPE)
        outs.append(y)
    return outs


# ---------------------------------------------------------- GQA attention ----

def init_attention(key, cfg: ModelConfig, max_seq: int):
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = _split(key, 4)
    return {
        "norm": init_norm(cfg, cfg.d_model),
        "wq": init_dense(k1, cfg.d_model, cfg.n_heads * hd, cfg.qkv_bias),
        "wk": init_dense(k2, cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias),
        "wv": init_dense(k3, cfg.d_model, cfg.n_kv_heads * hd, cfg.qkv_bias),
        "wo": init_dense(k4, cfg.n_heads * hd, cfg.d_model),
    }


def init_attn_cache(cfg: ModelConfig, batch: int, max_seq: int, window: int = 0):
    hd = cfg.resolved_head_dim
    s = min(max_seq, window) if window else max_seq
    shape = (batch, s, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, ACT_DTYPE),
        "v": jnp.zeros(shape, ACT_DTYPE),
    }


def _is_pos_vector(pos) -> bool:
    """True when ``pos`` is a per-row position vector [B] (batched serving
    decode) rather than a scalar shared across the batch."""
    return pos is not None and jnp.ndim(pos) == 1


def _decode_positions(pos, B: int, T: int) -> jax.Array:
    """[B, T] absolute positions of the decode step (T == 1 tokens)."""
    if _is_pos_vector(pos):
        return jnp.broadcast_to(jnp.asarray(pos, jnp.int32)[:, None], (B, T))
    return jnp.full((B, T), pos, dtype=jnp.int32)


def _cache_write(buf: jax.Array, new: jax.Array, write):
    """Write the decode-step entry ``new`` [B, 1, ...] into cache ``buf``
    [B, S, ...] at slot ``write`` — shared scalar (dynamic_update_slice) or
    per-row vector [B] (one scatter row per batch element)."""
    if _is_pos_vector(write):
        B = buf.shape[0]
        return buf.at[jnp.arange(B), write].set(new[:, 0])
    idx = (0, write) + (0,) * (buf.ndim - 2)
    return lax.dynamic_update_slice(buf, new, idx)


def _prefill_off(pos, mode: str):
    """Chunk offset of a prefill call: the engine's chunked prefill processes
    tokens [B, C] at absolute positions off..off+C-1. Two forms:

      * a Python int (bucketed per-batch chunking — every row of the batch
        shares one offset, each (bucket, chunk) shape traces once),
      * a TRACED int32 vector [B] (token-packed prefill — every row is a
        DIFFERENT request at its own offset, so ONE compiled shape serves
        every packing mix).

    Classic whole-prompt prefill passes pos=None -> offset 0."""
    if mode != "prefill" or pos is None:
        return 0
    if _is_pos_vector(pos):
        return jnp.asarray(pos, jnp.int32)
    return int(pos)


def _off_any(off) -> bool:
    """True when any row of this prefill call may start past position 0
    (an earlier chunk's cache/conv tail can exist). Always True for a
    per-row offset vector — rows at offset 0 read a zeroed cache row, which
    is bitwise identical to the fresh-state branch."""
    return _is_pos_vector(off) or bool(off)


def _conv_tail_state(xp: jax.Array, off, T: int, lengths,
                     d_conv: int) -> jax.Array:
    """Per-row depthwise-conv tail state of a bucketed prefill chunk:
    the last ``d_conv - 1`` REAL inputs per row, gathered from
    ``xp = [prev_tail (d_conv-1), inputs (T)]``. Index e..e+d_conv-2 ends
    at the row's last real position of this chunk; rows with no real
    positions (e = 0) keep the prior tail. Shared by Mamba and RG-LRU so
    the tail-index math can never diverge between them."""
    B = xp.shape[0]
    e = (jnp.clip(jnp.asarray(lengths, jnp.int32) - off, 0, T)
         if lengths is not None else jnp.full((B,), T, jnp.int32))
    gidx = e[:, None] + jnp.arange(d_conv - 1)[None]
    return jnp.take_along_axis(xp, gidx[..., None], axis=1).astype(ACT_DTYPE)


def _prefill_valid(off, T: int, lengths, *, time_major: bool = False):
    """[B, T] (or [T, B]) mask of REAL positions in a bucketed prefill
    chunk: global position off+t belongs to row b iff off+t < lengths_b.
    ``off`` is a shared int or a per-row vector [B] (token-packed prefill).
    None when lengths is None (whole batch real) — the single source of
    the bucket-padding validity invariant for every block type."""
    if lengths is None:
        return None
    L = jnp.asarray(lengths, jnp.int32)
    if _is_pos_vector(off):
        g = jnp.asarray(off, jnp.int32)[:, None] + jnp.arange(T)[None]
        m = g < L[:, None]  # [B, T]
        return m.T if time_major else m
    g = off + jnp.arange(T)
    if time_major:
        return g[:, None] < L[None, :]
    return g[None, :] < L[:, None]


def _window_prefill_write(cache: dict, k: jax.Array, v: jax.Array, *,
                          off, lengths, window: int):
    """Masked rolling-buffer write for a bucketed/chunked prefill step.

    Writes, per row, the last ``min(T, window)`` REAL positions before
    ``end_b = min(lengths_b, off + T)`` at slot p % window. Pad positions
    (>= lengths_b) and positions from earlier chunks (< off) leave the
    buffer untouched, so padding a prompt to its bucket can never clobber a
    previously written real key. Slot indices within a row are a contiguous
    position range of length <= window, hence collision-free. ``off`` is a
    shared int or a per-row offset vector [B] (token-packed prefill)."""
    B, T = k.shape[0], k.shape[1]
    off_b = jnp.asarray(off, jnp.int32) if _is_pos_vector(off) else off
    off_col = off_b[:, None] if _is_pos_vector(off) else off_b
    if lengths is None:
        end = jnp.full((B,), T, jnp.int32) + off_b
    else:
        end = jnp.clip(jnp.asarray(lengths, jnp.int32), off_b, off_b + T)
    keep = min(T, window)
    idx = end[:, None] - keep + jnp.arange(keep)[None]  # [B, keep] abs pos
    valid = idx >= off_col
    local = jnp.clip(idx - off_col, 0, T - 1)
    slots = idx % window
    bidx = jnp.arange(B)[:, None]

    def write(buf, new):
        sel = jnp.take_along_axis(new, local[..., None, None], axis=1)
        cur = buf[bidx, slots]
        return buf.at[bidx, slots].set(
            jnp.where(valid[..., None, None], sel, cur))

    return {"k": write(cache["k"], k), "v": write(cache["v"], v)}


def _cache_abs_pos(S: int, pos, window: int):
    """Absolute position of each cache slot during decode (-1 = not valid).

    Linear cache: slot s holds position s, valid while s <= pos.
    Rolling window cache: slot s holds the latest position congruent to s
    (mod window) that is <= pos.

    ``pos`` may be a scalar (-> [S]) or a per-row vector [B] (-> [B, S],
    the batched serving engine's per-slot positions)."""
    slot = jnp.arange(S)
    if _is_pos_vector(pos):
        slot = slot[None, :]
        pos = jnp.asarray(pos)[:, None]
    if not window:
        return jnp.where(slot <= pos, slot, -1)
    base = (pos // window) * window
    abs_pos = jnp.where(slot <= pos % window, base + slot, base - window + slot)
    ok = (abs_pos >= 0) & (abs_pos <= pos) & (abs_pos > pos - window)
    return jnp.where(ok, abs_pos, -1)


def apply_attention(
    p,
    x,
    *,
    cfg: ModelConfig,
    cache=None,
    pos=None,
    mode="train",
    window: int = 0,
    rope_theta: Optional[float] = None,
    cross_kv=None,
    lengths=None,
    ft=None,
):
    """GQA/MQA attention with optional sliding window and KV cache.

    cross_kv: precomputed (k, v) for cross-attention (whisper decoder);
    bypasses self-KV entirely (no mask, no rope).

    ``ft`` (serving): protection context — scope ``qkv`` runs the Q/K/V
    projections as entangled int8 GEMMs with fail-stop roll-forward.

    Batched/chunked prefill: ``pos`` (a static int) is the chunk offset and
    ``lengths`` [B] the per-row true prompt lengths of a bucket-padded
    batch — cache writes are offset (linear) or length-masked (rolling
    window), and chunk queries attend to all earlier cached positions.

    Token-packed prefill: ``pos`` is a TRACED int32 vector [B] of per-row
    chunk offsets (each row a different request). Linear cache writes become
    per-row scatters and queries attend over the FULL cache with a per-row
    causal mask — masked tail keys contribute exact 0.0 to the softmax
    reductions, so packed output is bitwise identical to per-batch chunking.
    """
    B, T, D = x.shape
    hd = cfg.resolved_head_dim
    H, Hkv = cfg.n_heads, cfg.n_kv_heads
    off = _prefill_off(pos, mode)
    vec_off = _is_pos_vector(off)
    h = apply_norm(p["norm"], x, cfg)

    win_kabs = None  # set on the bucketed/chunked rolling-window path
    win_qpos = None
    packed_qpos = None  # set on the token-packed linear-cache path
    if cross_kv is None:
        # Q/K/V consume the same normed activations: one fanout group
        # (a protected run shares a single quantize+group codec pass)
        q, k, v = dense_fanout((p["wq"], p["wk"], p["wv"]), h, ft=ft,
                               sites=("qkv.q", "qkv.k", "qkv.v"))
        q = q.reshape(B, T, H, hd)
        k = k.reshape(B, T, Hkv, hd)
        v = v.reshape(B, T, Hkv, hd)
        if rope_theta:
            if mode == "decode":
                positions = _decode_positions(pos, B, T)
            elif vec_off:
                positions = off[:, None] + jnp.arange(T)[None]
            else:
                positions = jnp.broadcast_to(jnp.arange(T) + off, (B, T))
            q = rope(q, positions, rope_theta)
            k = rope(k, positions, rope_theta)
        q = constrain(q, "batch", "seq", "heads", None)
        k = constrain(k, "batch", "seq", "kv_heads", None)
        v = constrain(v, "batch", "seq", "kv_heads", None)
        new_cache = cache
        if mode == "decode":
            assert cache is not None
            S = cache["k"].shape[1]
            write = (pos % window) if window else pos
            k_all = _cache_write(cache["k"], k, write)
            v_all = _cache_write(cache["v"], v, write)
            new_cache = {"k": k_all, "v": v_all}
            k, v = k_all, v_all
            Tk = S
        elif mode == "prefill":
            assert cache is not None
            batched = lengths is not None or vec_off or off > 0
            if window:
                if batched:
                    new_cache = _window_prefill_write(
                        cache, k, v, off=off, lengths=lengths, window=window)
                    # attend against OLD cache (earlier chunks) + own keys,
                    # masked on per-row absolute positions: a row's pad tail
                    # and other rows' lengths can't leak into its window
                    S_c = cache["k"].shape[1]
                    prev_end = (jnp.clip(jnp.asarray(lengths, jnp.int32),
                                         0, off)
                                if lengths is not None
                                else jnp.full((B,), off, jnp.int32))
                    kabs_cache = _cache_abs_pos(S_c, prev_end - 1, window)
                    g = (off[:, None] + jnp.arange(T)[None] if vec_off
                         else off + jnp.arange(T))
                    valid_new = _prefill_valid(off, T, lengths)
                    if valid_new is None:
                        valid_new = jnp.ones((B, T), bool)
                    kabs_new = jnp.where(valid_new,
                                         g if vec_off else g[None, :], -1)
                    win_kabs = jnp.concatenate([kabs_cache, kabs_new], axis=1)
                    win_qpos = g
                    k = jnp.concatenate([cache["k"], k], axis=1)
                    v = jnp.concatenate([cache["v"], v], axis=1)
                    Tk = S_c + T
                else:
                    # rolling buffer: absolute pos p lives at slot p % window
                    keep = min(T, window)
                    slots = jnp.arange(T - keep, T) % window
                    new_cache = {
                        "k": cache["k"].at[:, slots].set(k[:, T - keep :]),
                        "v": cache["v"].at[:, slots].set(v[:, T - keep :]),
                    }
                    Tk = T
            elif vec_off:
                # token-packed: per-row scatter write, then attend over the
                # FULL cache with a per-row causal mask (masked tail keys
                # contribute exact zeros — bitwise-equal to the slice path)
                bidx = jnp.arange(B)[:, None]
                idx = off[:, None] + jnp.arange(T)[None]  # [B, T] abs pos
                new_cache = {
                    "k": cache["k"].at[bidx, idx].set(k),
                    "v": cache["v"].at[bidx, idx].set(v),
                }
                k, v = new_cache["k"], new_cache["v"]
                Tk = cache["k"].shape[1]
                packed_qpos = idx
            else:
                new_cache = {
                    "k": lax.dynamic_update_slice(cache["k"], k,
                                                  (0, off, 0, 0)),
                    "v": lax.dynamic_update_slice(cache["v"], v,
                                                  (0, off, 0, 0)),
                }
                if off:
                    # chunked: queries attend to every position cached so far
                    k = new_cache["k"][:, : off + T]
                    v = new_cache["v"][:, : off + T]
                    Tk = off + T
                else:
                    Tk = T
        else:
            Tk = T
    else:
        q = dense(p["wq"], h, ft=ft, site="qkv.q").reshape(B, T, H, hd)
        k, v = cross_kv
        Tk = k.shape[1]
        new_cache = cache

    # grouped heads: q [B, Hkv, G, T, hd]; k/v [B, Hkv, S, hd]
    from repro.models.attention_core import (
        attend, attend_decode, attend_prefill_packed, attend_prefill_window)

    G = H // Hkv
    qg = q.reshape(B, T, Hkv, G, hd).transpose(0, 2, 3, 1, 4)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if cross_kv is not None:
        o = attend(qg, kt, vt, kind="full")
    elif mode == "decode":
        abs_pos = _cache_abs_pos(Tk, pos, window)
        o = attend_decode(qg, kt, vt, abs_pos=abs_pos)
    elif mode == "encode":
        o = attend(qg, kt, vt, kind="full")
    elif win_kabs is not None:
        o = attend_prefill_window(qg, kt, vt, qpos=win_qpos,
                                  kabs=win_kabs, window=window)
    elif packed_qpos is not None:
        o = attend_prefill_packed(qg, kt, vt, qpos=packed_qpos)
    else:
        o = attend(qg, kt, vt, kind="window" if window else "causal",
                   window=window, q_off=off)
    out = o.transpose(0, 3, 1, 2, 4).reshape(B, T, H * hd)
    out = dense(p["wo"], out.astype(ACT_DTYPE), ft=ft, site="out.o")
    return constrain(out, "batch", "seq", "embed"), new_cache


# ---------------------------------------------------------- MLA attention ----

def init_mla(key, cfg: ModelConfig, max_seq: int):
    m = cfg.mla
    ks = _split(key, 6)
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {
        "norm": init_norm(cfg, cfg.d_model),
        "wkv_a": init_dense(ks[0], cfg.d_model, m.kv_lora_rank + m.qk_rope_head_dim),
        "kv_norm": init_norm(cfg, m.kv_lora_rank),
        "wkv_b": init_dense(
            ks[1], m.kv_lora_rank, cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
        ),
        "wo": init_dense(ks[2], cfg.n_heads * m.v_head_dim, cfg.d_model),
    }
    if m.q_lora_rank:
        p["wq_a"] = init_dense(ks[3], cfg.d_model, m.q_lora_rank)
        p["q_norm"] = init_norm(cfg, m.q_lora_rank)
        p["wq_b"] = init_dense(ks[4], m.q_lora_rank, cfg.n_heads * qk_dim)
    else:
        p["wq"] = init_dense(ks[5], cfg.d_model, cfg.n_heads * qk_dim)
    return p


def init_mla_cache(cfg: ModelConfig, batch: int, max_seq: int):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_seq, m.kv_lora_rank), ACT_DTYPE),
        "krope": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), ACT_DTYPE),
    }


def apply_mla(p, x, *, cfg: ModelConfig, cache=None, pos=None, mode="train",
              lengths=None, ft=None):
    """Multi-head latent attention (DeepSeek). The cache stores ONLY the
    compressed latent c_kv [B, S, r] + shared k_rope — the paper-faithful
    KV-compression; decode up-projects cached latents (the absorbed-weight
    variant is a recorded §Perf hillclimb candidate).

    Chunked prefill: ``pos`` (static int) offsets rope positions and the
    latent-cache write; chunk queries attend over all cached latents so
    far. Bucket padding needs no masking here (linear cache + causal mask:
    garbage latents past a row's length are never read by real queries and
    are decode-overwritten before they become visible).

    Token-packed prefill: ``pos`` is a traced per-row offset vector [B];
    cache writes become per-row scatters and queries attend over the full
    latent cache under a per-row causal mask (exact-zero masked terms)."""
    m = cfg.mla
    B, T, D = x.shape
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    off = _prefill_off(pos, mode)
    vec_off = _is_pos_vector(off)
    h = apply_norm(p["norm"], x, cfg)

    # wq_a (or wq) and wkv_a both project the normed residual stream:
    # one fanout group per step
    if m.q_lora_rank:
        qa, kv = dense_fanout((p["wq_a"], p["wkv_a"]), h, ft=ft,
                              sites=("qkv.q_a", "qkv.kv"))
        q = dense(p["wq_b"], apply_norm(p["q_norm"], qa, cfg),
                  ft=ft, site="qkv.q")
    else:
        q, kv = dense_fanout((p["wq"], p["wkv_a"]), h, ft=ft,
                             sites=("qkv.q", "qkv.kv"))
    q = q.reshape(B, T, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    # kv: [B, T, r + dr]
    ckv = apply_norm(p["kv_norm"], kv[..., : m.kv_lora_rank], cfg)
    k_rope_new = kv[..., m.kv_lora_rank :]  # [B, T, dr] shared across heads

    if mode == "decode":
        positions = _decode_positions(pos, B, T)
    elif vec_off:
        positions = off[:, None] + jnp.arange(T)[None]
    else:
        positions = jnp.broadcast_to(jnp.arange(T) + off, (B, T))
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    k_rope_new = rope(k_rope_new[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]

    new_cache = cache
    packed_qpos = None  # set on the token-packed path
    if mode == "decode":
        assert cache is not None
        ckv_all = _cache_write(cache["ckv"], ckv, pos)
        kr_all = _cache_write(cache["krope"], k_rope_new, pos)
        new_cache = {"ckv": ckv_all, "krope": kr_all}
        ckv_s, kr_s = ckv_all, kr_all
        Tk = ckv_all.shape[1]
    elif mode == "prefill" and vec_off:
        assert cache is not None
        bidx = jnp.arange(B)[:, None]
        idx = off[:, None] + jnp.arange(T)[None]
        new_cache = {
            "ckv": cache["ckv"].at[bidx, idx].set(ckv),
            "krope": cache["krope"].at[bidx, idx].set(k_rope_new),
        }
        ckv_s, kr_s = new_cache["ckv"], new_cache["krope"]
        Tk = cache["ckv"].shape[1]
        packed_qpos = idx
    else:
        if mode == "prefill":
            assert cache is not None
            new_cache = {
                "ckv": lax.dynamic_update_slice(cache["ckv"], ckv,
                                                (0, off, 0)),
                "krope": lax.dynamic_update_slice(cache["krope"], k_rope_new,
                                                  (0, off, 0)),
            }
        if mode == "prefill" and off:
            ckv_s = new_cache["ckv"][:, : off + T]
            kr_s = new_cache["krope"][:, : off + T]
            Tk = off + T
        else:
            ckv_s, kr_s = ckv, k_rope_new
            Tk = T

    from repro.models.attention_core import (attend, attend_decode,
                                             attend_prefill_packed)

    if mode == "decode" and cfg.mla_absorb:
        # absorbed projections: fold W_uk into q and W_uv out of the value
        # sum, so attention runs over the r-dim latents themselves and the
        # up-projection happens ONCE per step, not per cached position.
        wb = p["wkv_b"]["w"].reshape(m.kv_lora_rank, H, dn + dv)
        w_uk, w_uv = wb[..., :dn], wb[..., dn:]
        scale = 1.0 / math.sqrt(dn + dr)
        q_eff = jnp.einsum("bthd,rhd->bthr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        s_nope = jnp.einsum("bthr,bsr->bhts", q_eff,
                            ckv_s.astype(jnp.float32))
        s_rope = jnp.einsum("bthd,bsd->bhts", q_rope.astype(jnp.float32),
                            kr_s.astype(jnp.float32))
        s = (s_nope + s_rope) * scale
        slot = jnp.arange(Tk)
        if _is_pos_vector(pos):  # per-row positions: mask [B, S]
            ok = slot[None, :] <= jnp.asarray(pos)[:, None]
            s = jnp.where(ok[:, None, None, :], s, -1e30)
        else:
            s = jnp.where((slot <= pos)[None, None, None], s, -1e30)
        probs = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhts,bsr->bthr", probs, ckv_s.astype(jnp.float32))
        o = jnp.einsum("bthr,rhd->bthd", ctx, w_uv.astype(jnp.float32))
        out = dense(p["wo"], o.reshape(B, T, H * dv).astype(ACT_DTYPE),
                    ft=ft, site="out.o")
        return constrain(out, "batch", "seq", "embed"), new_cache

    # up-project latents to per-head K_nope and V (paper-faithful/naive path)
    kvb = dense(p["wkv_b"], ckv_s).reshape(B, Tk, H, dn + dv)
    k_nope, v = kvb[..., :dn], kvb[..., dn:]
    k_nope = constrain(k_nope, "batch", "seq", "heads", None)

    scale = 1.0 / math.sqrt(dn + dr)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_s[:, :, None, :], (B, Tk, H, dr))], axis=-1
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    qg = q_full.transpose(0, 2, 1, 3)[:, :, None]  # [B, H, 1, T, dk]
    kt = k_full.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if mode == "decode":
        o = attend_decode(qg, kt, vt, abs_pos=_cache_abs_pos(Tk, pos, 0),
                          scale=scale)
    elif packed_qpos is not None:
        o = attend_prefill_packed(qg, kt, vt, qpos=packed_qpos, scale=scale)
    else:
        o = attend(qg, kt, vt, kind="causal", scale=scale, q_off=off)
    out = o[:, :, 0].transpose(0, 2, 1, 3).reshape(B, T, H * dv)
    out = dense(p["wo"], out.astype(ACT_DTYPE), ft=ft, site="out.o")
    return constrain(out, "batch", "seq", "embed"), new_cache


# ------------------------------------------------------------------- MLP ----

def _mlp_gated(cfg: ModelConfig, gated_default: bool) -> bool:
    return gated_default if cfg.mlp_gated is None else cfg.mlp_gated


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None, gated=True):
    d_ff = d_ff or cfg.d_ff
    ks = _split(key, 3)
    if _mlp_gated(cfg, gated):
        return {
            "norm": init_norm(cfg, cfg.d_model),
            "gate": init_dense(ks[0], cfg.d_model, d_ff),
            "up": init_dense(ks[1], cfg.d_model, d_ff),
            "down": init_dense(ks[2], d_ff, cfg.d_model),
        }
    return {
        "norm": init_norm(cfg, cfg.d_model),
        "up": init_dense(ks[0], cfg.d_model, d_ff),
        "down": init_dense(ks[1], d_ff, cfg.d_model),
    }


def _mlp_act(cfg: ModelConfig, a):
    if cfg.mlp_act == "relu2":
        return jnp.square(jax.nn.relu(a))
    if cfg.mlp_act == "gelu":
        return jax.nn.gelu(a)
    return jax.nn.silu(a)


def apply_mlp(p, x, *, cfg: ModelConfig, ft=None):
    h = apply_norm(p["norm"], x, cfg)
    if "gate" in p:
        # gate/up share the normed input: one fanout group
        gate, up = dense_fanout((p["gate"], p["up"]), h, ft=ft,
                                sites=("mlp.gate", "mlp.up"))
        a = _mlp_act(cfg, gate) * up
    else:
        up = dense(p["up"], h, ft=ft, site="mlp.up")
        a = _mlp_act(cfg, up) if cfg.norm_kind != "layernorm" \
            else jax.nn.gelu(up)
    a = constrain(a, "batch", "seq", "mlp")
    return constrain(dense(p["down"], a, ft=ft, site="mlp.down"),
                     "batch", "seq", "embed")


# ------------------------------------------------------------------- MoE ----

def _moe_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    mc = cfg.moe
    c = int(math.ceil(n_tokens * mc.top_k / mc.n_experts * mc.capacity_factor))
    c = min(c, n_tokens * mc.top_k)  # dropless ceiling
    return max(8, -(-c // 8) * 8)  # round up to 8


def init_moe(key, cfg: ModelConfig):
    mc = cfg.moe
    ks = _split(key, 5)
    p = {
        "norm": init_norm(cfg, cfg.d_model),
        "router": _he(ks[0], (cfg.d_model, mc.n_experts)),
        "we_gate": _he(ks[1], (mc.n_experts, cfg.d_model, mc.d_ff_expert), cfg.d_model),
        "we_up": _he(ks[2], (mc.n_experts, cfg.d_model, mc.d_ff_expert), cfg.d_model),
        "we_down": _he(ks[3], (mc.n_experts, mc.d_ff_expert, cfg.d_model), mc.d_ff_expert),
    }
    if mc.n_shared:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=mc.n_shared * mc.d_ff_expert)
        del p["shared"]["norm"]  # shares the block's norm
    return p


def apply_moe(p, x, *, cfg: ModelConfig, valid=None, ft=None):
    """Grouped sort-based dispatch (EP): tokens are routed SHARD-LOCALLY per
    data-parallel group (leading G axis = number of 'batch' shards), so the
    argsort/scatter never crosses devices; the only cross-device movement is
    the capacity-bounded [G, E, C, D] buffer resharding (data <-> expert
    owners) — GSPMD lowers it to the canonical EP all-to-all. §Perf iteration
    1: replaces a global argsort whose GSPMD lowering all-gathered the full
    [N, D] activations (collective-bound, see EXPERIMENTS.md).

    dispatch='global_sort' keeps the pre-iteration path for A/B.

    ``valid`` [B, T] (bucketed batched prefill) routes pad tokens to a
    virtual out-of-range expert so they can never STEAL capacity slots from
    real prompt tokens; their own outputs are garbage and discarded by the
    caller. (Capacity-factor dropping itself still depends on the batch
    composition, so MoE batched serving is exact only modulo drops — the
    same caveat as any capacity-bounded MoE engine.)"""
    from repro.dist.sharding import axis_extent

    mc = cfg.moe
    B, T, D = x.shape
    N, E, K = B * T, mc.n_experts, mc.top_k
    h = apply_norm(p["norm"], x, cfg)
    hf = h.reshape(N, D)

    G = axis_extent("batch") if getattr(mc, "dispatch", "grouped") == "grouped" else 1
    if N % G:
        G = 1
    n_loc = N // G
    hg = constrain(hf.reshape(G, n_loc, D), "batch", None, None)

    # router in bf16 with f32 accumulation: avoids materializing an f32
    # copy of the full [N, D] activations (§Perf iteration 4)
    if ft is not None and ft.protects("mlp.router"):
        # MoE routing decisions steer EVERY expert GEMM downstream —
        # protecting this small projection makes routing itself fail-stop
        # recoverable, so a failed group cannot silently reroute tokens
        rw = ((p["router_q8"]["w"], p["router_q8"]["scale"])
              if "router_q8" in p else p["router"])
        logits = ft.matmul("mlp.router", hg, rw)
    else:
        logits = jnp.einsum("gnd,de->gne", hg,
                            p["router"].astype(ACT_DTYPE),
                            preferred_element_type=jnp.float32)
    if mc.gating == "sigmoid":
        probs = jax.nn.sigmoid(logits)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = lax.top_k(probs, K)  # [G, n_loc, K]
    weights = vals / (jnp.sum(vals, -1, keepdims=True) + 1e-9)

    C = _moe_capacity(n_loc, cfg)
    A = n_loc * K  # assignments per group
    if valid is not None:
        vg = valid.reshape(G, n_loc)
        idx = jnp.where(vg[..., None], idx, E)  # pad tokens -> virtual expert
    e_flat = idx.reshape(G, A)
    w_flat = weights.reshape(G, A)
    order = jnp.argsort(e_flat, axis=-1)  # stable: within-expert order = token order
    e_s = jnp.take_along_axis(e_flat, order, axis=-1)
    starts = jax.vmap(
        lambda es: jnp.searchsorted(es, jnp.arange(E + 1), side="left"))(e_s)

    # GATHER-based capacity dispatch (§Perf iteration 3): buffer slot
    # p = e*C + r pulls sorted-assignment starts[e]+r — no forward scatter
    # (XLA's scatter expander materializes target-shaped index grids).
    eidx = jnp.arange(E * C) // C
    ridx = jnp.arange(E * C) % C
    src = jnp.take_along_axis(starts, eidx[None].repeat(G, 0), axis=1) + ridx
    # slot occupancy mask — deliberately NOT named `valid`: that's the
    # [B, T] token-validity parameter, still live below
    slot_ok = src < jnp.take_along_axis(starts, eidx[None].repeat(G, 0) + 1,
                                        axis=1)
    src = jnp.minimum(src, A - 1)
    src_assign = jnp.take_along_axis(order, src, axis=1)  # [G, E*C] assignment id
    src_tok = src_assign // K
    rows = jnp.take_along_axis(hg, src_tok[..., None], axis=1)  # [G, E*C, D]
    rows = constrain(rows, "batch", None, None)
    expert_in = jnp.where(slot_ok[..., None], rows, 0).reshape(G, E, C, D)
    # the EP boundary: data-sharded groups -> expert-sharded buffers
    expert_in = constrain(expert_in, "batch", "experts", None, None)
    if ft is not None and ft.protects("moe.gate"):
        # the per-expert batched GEMMs — the last big unprotected FLOPs of
        # the MoE block — run through the GROUPED entangled kernel: one
        # call per projection covers all E experts, rows round-robin onto
        # the M streams within each expert, fail-stop rolled forward
        # per-expert in-kernel. Startup-quantized q8 stacks (per-expert
        # grids) are used when prepare_params installed them.
        def _we(name):
            q = p.get(name + "_q8")
            return (q["w"], q["scale"]) if q is not None else p[name]

        a = jax.nn.silu(
            ft.matmul_grouped("moe.gate", expert_in, _we("we_gate"))
        ).astype(ACT_DTYPE) * ft.matmul_grouped(
            "moe.up", expert_in, _we("we_up")).astype(ACT_DTYPE)
        out_e = ft.matmul_grouped("moe.down", a,
                                  _we("we_down")).astype(ACT_DTYPE)
    else:
        a = jax.nn.silu(
            jnp.einsum("gecd,edf->gecf", expert_in,
                       p["we_gate"].astype(ACT_DTYPE))
        ) * jnp.einsum("gecd,edf->gecf", expert_in,
                       p["we_up"].astype(ACT_DTYPE))
        out_e = jnp.einsum("gecf,efd->gecd", a,
                           p["we_down"].astype(ACT_DTYPE))
    out_e = constrain(out_e, "batch", "experts", None, None)
    h_flat = constrain(out_e.reshape(G, E * C, D), "batch", None, None)

    # combine, also gather-based: assignment (t, k) sits at sorted position
    # inv_order, rank within its expert = pos - starts[e], slot = e*C + rank
    inv_order = jnp.argsort(order, axis=-1)  # [G, A]
    rank = inv_order - jnp.take_along_axis(starts, e_flat, axis=1)
    keep = rank < C
    if valid is not None:
        keep &= e_flat < E  # virtual-expert (pad) assignments contribute 0
    slot = jnp.minimum(e_flat * C + rank, E * C - 1)
    hsel = jnp.take_along_axis(h_flat, slot[..., None], axis=1)  # [G, A, D]
    hsel = constrain(hsel, "batch", None, None)
    contrib = jnp.where(keep[..., None],
                        w_flat[..., None].astype(ACT_DTYPE) * hsel, 0)
    out = contrib.reshape(G, n_loc, K, D).sum(axis=2)
    out = constrain(out, "batch", None, None).reshape(N, D)

    if mc.n_shared:
        sp = dict(p["shared"])
        g_s, u_s = dense_fanout((sp["gate"], sp["up"]), hf, ft=ft,
                                sites=("mlp.gate", "mlp.up"))
        a = jax.nn.silu(g_s) * u_s
        out = out + dense(sp["down"], a, ft=ft, site="mlp.down")
    return constrain(out.reshape(B, T, D), "batch", "seq", "embed")


# ----------------------------------------------------------------- Mamba ----

def _mamba_dims(cfg: ModelConfig):
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    dt_rank = sc.dt_rank or -(-cfg.d_model // 16)
    return d_inner, dt_rank


def init_mamba(key, cfg: ModelConfig, max_seq: int):
    sc = cfg.ssm
    di, dtr = _mamba_dims(cfg)
    ks = _split(key, 6)
    return {
        "norm": init_norm(cfg, cfg.d_model),
        "in_proj": init_dense(ks[0], cfg.d_model, 2 * di),
        "conv_w": _he(ks[1], (di, sc.d_conv), sc.d_conv),
        "conv_b": jnp.zeros((di,)),
        "x_proj": init_dense(ks[2], di, dtr + 2 * sc.d_state),
        "dt_proj": {
            "w": _he(ks[3], (dtr, di)),
            "b": jnp.zeros((di,)) + jnp.log(jnp.expm1(jnp.float32(0.01))),
        },
        "A_log": jnp.log(
            jnp.broadcast_to(jnp.arange(1, sc.d_state + 1, dtype=jnp.float32), (di, sc.d_state))
        ),
        "D_skip": jnp.ones((di,)),
        "out_proj": init_dense(ks[4], di, cfg.d_model),
    }


def init_mamba_cache(cfg: ModelConfig, batch: int, max_seq: int):
    sc = cfg.ssm
    di, _ = _mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, sc.d_conv - 1, di), ACT_DTYPE),
        "h": jnp.zeros((batch, di, sc.d_state), jnp.float32),
    }


def apply_mamba(p, x, *, cfg: ModelConfig, cache=None, pos=None, mode="train",
                lengths=None, ft=None):
    """Mamba-1: GEMMs hoisted out of the recurrence; the selective scan runs
    as lax.scan over time (compile-compact; per-step work is elementwise).

    Bucketed/chunked prefill: ``pos`` (static int) is the chunk offset —
    the depthwise conv is seeded from the cached tail of the previous chunk
    — and ``lengths`` [B] gates the recurrence per row, so a bucket-padded
    prompt's pad tail can NEVER leak into the carried state (recurrent
    state, unlike a causally masked KV cache, would otherwise absorb every
    pad token)."""
    sc = cfg.ssm
    B, T, D = x.shape
    di, dtr = _mamba_dims(cfg)
    off = _prefill_off(pos, mode)
    h_in = apply_norm(p["norm"], x, cfg)
    # in_proj is Mamba's QKV analog (the block's big input projection)
    xz = dense(p["in_proj"], h_in, ft=ft, site="qkv.in")
    xs, z = xz[..., :di], xz[..., di:]
    xs = constrain(xs, "batch", "seq", "mlp")

    # depthwise causal conv over time
    new_conv_state = None
    if mode == "decode":
        window = jnp.concatenate([cache["conv"], xs], axis=1)  # [B, d_conv, di]
        new_conv_state = window[:, 1:]
        conv = jnp.einsum("bkd,dk->bd", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))[:, None]
    else:
        # chunk > 0: the conv context is the previous chunk's cached tail
        # (token-packed rows at offset 0 read a zeroed cache row — bitwise
        # identical to the fresh-state branch)
        pad = (cache["conv"].astype(xs.dtype) if _off_any(off)
               else jnp.zeros((B, sc.d_conv - 1, di), xs.dtype))
        xp = jnp.concatenate([pad, xs], axis=1)
        conv = sum(
            xp[:, j : j + T].astype(jnp.float32)
            * p["conv_w"][:, j].astype(jnp.float32)
            for j in range(sc.d_conv)
        )
        if mode == "prefill":
            if lengths is not None or _off_any(off):
                new_conv_state = _conv_tail_state(xp, off, T, lengths,
                                                  sc.d_conv)
            else:
                new_conv_state = xp[:, -(sc.d_conv - 1) :].astype(ACT_DTYPE)
    u = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32))  # [B, T, di] f32

    proj = dense(p["x_proj"], u.astype(ACT_DTYPE)).astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", proj[..., :dtr], p["dt_proj"]["w"].astype(jnp.float32))
        + p["dt_proj"]["b"]
    )
    Bc = proj[..., dtr : dtr + sc.d_state]  # [B, T, S]
    Cc = proj[..., dtr + sc.d_state :]
    A = -jnp.exp(p["A_log"])  # [di, S]

    h0 = cache["h"] if cache is not None else jnp.zeros((B, di, sc.d_state), jnp.float32)

    # Selective scan, chunked: the [B, T, di, S] discretized operands are
    # NEVER materialized over full T (17 TB/device at train_4k for 7B) —
    # da/db are formed per step inside the scan; chunk bodies are
    # checkpointed so backward stores only T/Q chunk-boundary states.
    # Bucketed prefill gates the state update per row/step (pad steps are
    # identities on h), keeping padded rows' carried state exact.
    valid_tb = (_prefill_valid(off, T, lengths, time_major=True)
                if mode == "prefill" else None)

    def step(h, inputs):
        if valid_tb is None:
            dt_t, b_t, c_t, u_t = inputs  # [B, di], [B, S], [B, S], [B, di]
        else:
            dt_t, b_t, c_t, u_t, v_t = inputs
        da_t = jnp.exp(dt_t[..., None] * A)  # [B, di, S]
        db_t = (dt_t * u_t)[..., None] * b_t[:, None, :]
        h_new = da_t * h + db_t
        h = h_new if valid_tb is None else jnp.where(v_t[:, None, None],
                                                     h_new, h)
        y = jnp.einsum("bds,bs->bd", h, c_t)
        return h, y

    xs = (
        dt.swapaxes(0, 1),  # [T, B, di]
        Bc.swapaxes(0, 1),  # [T, B, S]
        Cc.swapaxes(0, 1),
        u.swapaxes(0, 1),  # [T, B, di]
    )
    if valid_tb is not None:
        xs = xs + (valid_tb,)
    Q = 64  # chunk length
    if T % Q == 0 and T > Q:
        chunked = jax.tree.map(lambda a: a.reshape(T // Q, Q, *a.shape[1:]), xs)

        def chunk_body(h, chunk_xs):
            return lax.scan(step, h, chunk_xs)

        if mode == "train":
            chunk_body = jax.checkpoint(chunk_body)
        hT, ys = lax.scan(chunk_body, h0, chunked)
        ys = ys.reshape(T, B, di)
    else:
        hT, ys = lax.scan(step, h0, xs)
    y = ys.swapaxes(0, 1) + u * p["D_skip"].astype(jnp.float32)  # [B, T, di]
    y = y.astype(ACT_DTYPE) * jax.nn.silu(z)
    out = dense(p["out_proj"], y, ft=ft, site="out.o")
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"conv": new_conv_state.astype(ACT_DTYPE), "h": hT}
    return constrain(out, "batch", "seq", "embed"), new_cache


# ---------------------------------------------------------------- RG-LRU ----

def init_rglru(key, cfg: ModelConfig, max_seq: int):
    rc = cfg.rglru
    w = rc.lru_width or cfg.d_model
    ks = _split(key, 7)
    return {
        "norm": init_norm(cfg, cfg.d_model),
        "in_x": init_dense(ks[0], cfg.d_model, w),
        "in_gate": init_dense(ks[1], cfg.d_model, w),
        "conv_w": _he(ks[2], (w, rc.d_conv), rc.d_conv),
        "conv_b": jnp.zeros((w,)),
        "w_a": init_dense(ks[3], w, w, bias=True),
        "w_i": init_dense(ks[4], w, w, bias=True),
        "lam": jnp.full((w,), 4.0),  # a = sigmoid(lam)^(c*r): init near 0.98^c
        "out": init_dense(ks[5], w, cfg.d_model),
    }


def init_rglru_cache(cfg: ModelConfig, batch: int, max_seq: int):
    rc = cfg.rglru
    w = rc.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, rc.d_conv - 1, w), ACT_DTYPE),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def apply_rglru(p, x, *, cfg: ModelConfig, cache=None, pos=None, mode="train",
                lengths=None, ft=None):
    """RG-LRU block. Bucketed/chunked prefill mirrors :func:`apply_mamba`:
    ``pos`` (static int) seeds the conv from the previous chunk's cached
    tail, ``lengths`` gates the recurrence so pad steps hold the state."""
    rc = cfg.rglru
    B, T, D = x.shape
    w = rc.lru_width or cfg.d_model
    off = _prefill_off(pos, mode)
    h_in = apply_norm(p["norm"], x, cfg)
    # in_x / in_gate are the RG-LRU block's QKV-analog input projections;
    # they share h_in, so a protected run fans them out as one group
    gate_p, u = dense_fanout((p["in_gate"], p["in_x"]), h_in, ft=ft,
                             sites=("qkv.gate", "qkv.in"))
    gate = jax.nn.gelu(gate_p)

    new_conv_state = None
    if mode == "decode":
        windowv = jnp.concatenate([cache["conv"], u], axis=1)
        new_conv_state = windowv[:, 1:]
        u = jnp.einsum(
            "bkd,dk->bd", windowv.astype(jnp.float32), p["conv_w"].astype(jnp.float32)
        )[:, None] + p["conv_b"].astype(jnp.float32)
    else:
        pad = (cache["conv"].astype(u.dtype) if _off_any(off)
               else jnp.zeros((B, rc.d_conv - 1, w), u.dtype))
        up = jnp.concatenate([pad, u], axis=1)
        if mode == "prefill":
            if lengths is not None or _off_any(off):
                new_conv_state = _conv_tail_state(up, off, T, lengths,
                                                  rc.d_conv)
            else:
                new_conv_state = up[:, -(rc.d_conv - 1) :].astype(ACT_DTYPE)
        u = sum(
            up[:, j : j + T].astype(jnp.float32) * p["conv_w"][:, j].astype(jnp.float32)
            for j in range(rc.d_conv)
        ) + p["conv_b"].astype(jnp.float32)
    u = u.astype(ACT_DTYPE)

    r = jax.nn.sigmoid(dense(p["w_a"], u).astype(jnp.float32))
    i = jax.nn.sigmoid(dense(p["w_i"], u).astype(jnp.float32))
    log_a_base = jax.nn.log_sigmoid(p["lam"].astype(jnp.float32))  # [w]
    log_a = rc.c * r * log_a_base  # [B, T, w]
    a = jnp.exp(log_a)
    gated_x = i * u.astype(jnp.float32)
    inp = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x

    h0 = cache["h"] if cache is not None else jnp.zeros((B, w), jnp.float32)

    valid_tb = (_prefill_valid(off, T, lengths, time_major=True)
                if mode == "prefill" else None)

    def step(h, ab):
        if valid_tb is None:
            a_t, x_t = ab
            h = a_t * h + x_t
        else:
            a_t, x_t, v_t = ab
            h = jnp.where(v_t[:, None], a_t * h + x_t, h)
        return h, h

    scan_xs = (a.swapaxes(0, 1), inp.swapaxes(0, 1))
    if valid_tb is not None:
        scan_xs = scan_xs + (valid_tb,)
    hT, hs = lax.scan(step, h0, scan_xs)
    rec = hs.swapaxes(0, 1).astype(ACT_DTYPE)  # [B, T, w]
    out = dense(p["out"], rec * gate, ft=ft, site="out.o")
    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {"conv": new_conv_state, "h": hT}
    return constrain(out, "batch", "seq", "embed"), new_cache
