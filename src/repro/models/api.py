"""Unified Model API over all architecture families.

    model = get_model(cfg)
    params = model.init(key, cfg, max_seq)
    logits = model.forward_train(params, batch, cfg)          # [B, T, V]
    cache  = model.init_cache(cfg, batch_size, max_seq)
    logits, cache = model.prefill(params, batch, cfg, cache)  # fills cache
    h, cache = model.prefill_chunk(params, tokens, cfg, cache,
                                   pos0=c, lengths=lens)      # [B, C, D]
    logits, cache = model.decode_step(params, tok, cache, pos, cfg)
    h, cache      = model.decode_hidden(params, tok, cache, pos, cfg)

``decode_step``/``decode_hidden`` accept ``pos`` as a scalar (whole batch at
one position) or an int32 vector [B] of per-row positions — the batched
serving engine decodes every active slot at its own position in ONE call.
``decode_hidden`` returns the final-norm'd hidden states [B, D] *before* the
vocab projection, so serving can route the head GEMM through the
FT-protected entangled int8 path (repro.ft.heads) instead;
``decode_step`` == head_project(decode_hidden).

``decode_hidden`` and ``prefill_chunk`` accept an optional ``ft`` kwarg —
a :class:`repro.ft.FTContext` threaded down to every block so the serving
engine's ``ft_scope`` can run the in-model projections (QKV, MLP + router,
the attention/SSM output projections, and the MoE per-expert GEMMs via the
grouped entangled kernel) as entangled int8 GEMMs with in-kernel fail-stop
roll-forward (``ft=None``, the default, is the unprotected fast path;
decoder-only). Protection parameters resolve from the engine's
ahead-of-time compiled plans, and the ``params`` passed in may carry
startup-quantized ``q8`` weight copies (``repro.ft.prepare_params``) that
the protected sites consume directly — the float masters stay
authoritative for every unprotected path.

``prefill_chunk`` is the batched/bucketed prefill contract (decoder-only):
``tokens`` [B, C] is one chunk of a bucket-padded prompt batch processed at
absolute positions ``pos0..pos0+C-1`` (``pos0`` a static Python int — one
trace per (bucket, chunk) shape), ``lengths`` [B] the true per-row prompt
lengths. Cache writes land at the chunk offset; rolling-window buffers and
recurrent states are length-masked so a row's bucket-pad tail never leaks
into its cache. Returns the final-norm'd hidden states [B, C, D] (the
serving engine gathers each row's ``lengths-1`` column and projects it via
head_project or the entangled FT head) and the filled cache.

``prefill_packed`` is the token-packed variant (decoder-only): every row of
``tokens`` [R, C] is one chunk of a DIFFERENT request and ``pos0`` is a
TRACED int32 vector [R] of per-row offsets, so one compiled [R, C] shape
serves every packing mix — the serving engine's fixed-budget token packer
(``ServeConfig.token_budget``) gathers rows from all in-flight admission
batches into this single program per step.

batch dicts:
  dense/moe/ssm/hybrid: {tokens [B,T]}
  vlm:    {tokens [B,T], patch_embeds [B,P,D]}   (frontend stub)
  encdec: {tokens [B,T], frames [B,F,D]}         (conv frontend stub)
"""
from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import transformer as T


class Model(NamedTuple):
    init: Callable
    forward_train: Callable
    prefill: Callable
    prefill_chunk: Callable  # bucketed/chunked batched prefill (serving)
    prefill_packed: Callable  # token-packed prefill (per-row traced offsets)
    decode_step: Callable
    decode_hidden: Callable  # pre-head hidden states for the FT serving path
    head_project: Callable  # (params, h [B, D], cfg) -> logits [B, V]
    head_weights: Callable  # (params, cfg) -> [D, V] f32 head matrix
    init_cache: Callable


# ------------------------------------------------------------- decoder-only --

def _dec_init(key, cfg: ModelConfig, max_seq: int):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"embed": T.init_embed(k1, cfg, max_seq), "stack": T.init_stack(k2, cfg, max_seq)}
    if cfg.mtp:
        km1, km2 = jax.random.split(k3)
        p["mtp"] = {
            "proj": L.init_dense(km1, 2 * cfg.d_model, cfg.d_model),
            "block": jax.tree.map(
                lambda x: x[None],  # repeat=1 stacked unit
                T.BLOCKS["attn_dense"].init(km2, cfg, max_seq),
            ),
            "norm_h": L.init_norm(cfg, cfg.d_model),
            "norm_e": L.init_norm(cfg, cfg.d_model),
        }
    return p


def _prefix_embeds(p, batch, cfg: ModelConfig):
    """Token embeddings, with VLM patch embeddings prepended when present."""
    x = T.embed_tokens(p["embed"], batch["tokens"], cfg)
    if "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(L.ACT_DTYPE), x], axis=1)
        x = constrain(x, "batch", "seq", "embed")
    return x


def _mtp_logits(p, h, batch, cfg: ModelConfig):
    """DeepSeek-V3 multi-token prediction: one extra block predicting t+2
    from [norm(h_t); norm(emb(tok_{t+1}))], sharing embedding and head."""
    tokens = batch["tokens"]
    emb_next = T.embed_tokens({"tok": p["embed"]["tok"]},
                              jnp.roll(tokens, -1, axis=1), cfg)
    hh = jnp.concatenate(
        [L.apply_norm(p["mtp"]["norm_h"], h, cfg),
         L.apply_norm(p["mtp"]["norm_e"], emb_next, cfg)], axis=-1)
    x = L.dense(p["mtp"]["proj"], hh)

    def body(carry, p_i):
        y, _ = T.BLOCKS["attn_dense"].apply(
            p_i, carry, cfg=cfg, cache=None, pos=None, mode="train")
        return y, 0

    x, _ = lax.scan(body, x, p["mtp"]["block"])
    return T.logits_head(p["embed"], x, cfg)


def _dec_forward_train(p, batch, cfg: ModelConfig):
    x = _prefix_embeds(p, batch, cfg)
    h, _ = T.apply_stack(p["stack"], x, cfg=cfg, mode="train")
    if "patch_embeds" in batch:  # only text positions produce logits
        n_p = batch["patch_embeds"].shape[1]
        h = h[:, n_p:]
    logits = T.logits_head(p["embed"], h, cfg)
    if cfg.mtp:
        mtp_logits = _mtp_logits(p, h, batch, cfg)
        return logits, mtp_logits
    return logits


def _dec_init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    return T.init_stack_cache(cfg, batch, max_seq)


def _dec_prefill(p, batch, cfg: ModelConfig, cache):
    x = _prefix_embeds(p, batch, cfg)
    h, new_cache = T.apply_stack(p["stack"], x, cfg=cfg, caches=cache, mode="prefill")
    logits = T.logits_head(p["embed"], h[:, -1:], cfg)
    return logits[:, 0], new_cache


def _dec_prefill_chunk(p, tokens, cfg: ModelConfig, cache, *, pos0: int = 0,
                       lengths=None, ft=None):
    """Bucketed/chunked batched prefill: tokens [B, C] at absolute positions
    pos0..pos0+C-1 with per-row true lengths. Returns final-norm'd hidden
    states [B, C, D] + filled cache (see the module docstring). ``ft`` is
    the serving protection context (repro.ft.FTContext) — with a scope
    beyond ``head`` the chunk's QKV/MLP/router GEMMs run entangled, so a
    fail-stop during admission rolls forward inside those kernels too."""
    x = T.embed_tokens(p["embed"], tokens, cfg, pos=(pos0 or None))
    h, new_cache = T.apply_stack(p["stack"], x, cfg=cfg, caches=cache,
                                 pos=pos0, mode="prefill", lengths=lengths,
                                 ft=ft)
    return T.final_hidden(p["embed"], h, cfg), new_cache


def _dec_prefill_packed(p, tokens, cfg: ModelConfig, cache, *, pos0,
                        lengths=None, ft=None):
    """Token-packed prefill: tokens [R, C] where every ROW is one chunk of a
    DIFFERENT request, row r at absolute positions pos0[r]..pos0[r]+C-1.
    ``pos0`` is a TRACED int32 vector [R] (not static like prefill_chunk's
    offset), so ONE compiled [R, C] shape serves every mix of co-packed
    requests/offsets; ``lengths`` [R] are the rows' true prompt lengths.

    ``cache`` holds the R rows' per-request state (the engine gathers them
    from its slot-indexed staging cache by token metadata and zeroes rows
    starting at offset 0). Linear KV/latent caches are written by per-row
    scatter and attended over their full extent under a per-row causal
    mask; rolling-window buffers and the Mamba/RG-LRU conv tails + carried
    states use the same length-masked machinery as prefill_chunk with the
    offset broadcast per row — all bitwise identical to per-batch chunking
    (masked attention terms are exact zeros; recurrences are gated
    identities on pad steps). Returns final-norm'd hidden states [R, C, D]
    + the filled row cache."""
    pos0 = jnp.asarray(pos0, jnp.int32)
    grid = pos0[:, None] + jnp.arange(tokens.shape[1])[None]
    x = T.embed_tokens(p["embed"], tokens, cfg,
                       pos=(grid if "pos" in p["embed"] else None))
    h, new_cache = T.apply_stack(p["stack"], x, cfg=cfg, caches=cache,
                                 pos=pos0, mode="prefill", lengths=lengths,
                                 ft=ft)
    return T.final_hidden(p["embed"], h, cfg), new_cache


def _dec_decode_hidden(p, tok, cache, pos, cfg: ModelConfig, ft=None):
    x = T.embed_tokens(p["embed"], tok, cfg, pos=pos)
    h, new_cache = T.apply_stack(p["stack"], x, cfg=cfg, caches=cache,
                                 pos=pos, mode="decode", ft=ft)
    return T.final_hidden(p["embed"], h, cfg)[:, 0], new_cache


def _dec_decode(p, tok, cache, pos, cfg: ModelConfig):
    h, new_cache = _dec_decode_hidden(p, tok, cache, pos, cfg)
    logits = T.head_project(p["embed"], h[:, None], cfg)
    return logits[:, 0], new_cache


def _head_project(p, h, cfg: ModelConfig):
    """Vocab projection of decode-shaped hidden states h [B, D]."""
    return T.head_project(p["embed"], h[:, None], cfg)[:, 0]


def _head_weights(p, cfg: ModelConfig):
    """The [D, V] head matrix (shared-embedding transpose when tied) — what
    the serving engine int8-quantizes once for the entangled logits path."""
    w = p["embed"]["tok"].T if cfg.tie_embeddings else p["embed"]["head"]
    return w.astype(jnp.float32)


DECODER_MODEL = Model(
    init=_dec_init,
    forward_train=_dec_forward_train,
    prefill=_dec_prefill,
    prefill_chunk=_dec_prefill_chunk,
    prefill_packed=_dec_prefill_packed,
    decode_step=_dec_decode,
    decode_hidden=_dec_decode_hidden,
    head_project=_head_project,
    head_weights=_head_weights,
    init_cache=_dec_init_cache,
)


# ----------------------------------------------------------------- enc-dec --

def _sinusoid(n: int, d: int):
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _xattn_init(key, cfg, max_seq):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self": L.init_attention(k1, cfg, max_seq),
        "cross": L.init_attention(k2, cfg, max_seq),
        "mlp": L.init_mlp(k3, cfg, gated=False),
    }


def _xattn_apply(p, x, *, cfg, cache, pos, mode):
    a, nself = L.apply_attention(
        p["self"], x, cfg=cfg, cache=None if cache is None else cache["self"],
        pos=pos, mode=mode, rope_theta=None)
    x = x + a
    cross_kv = None if cache is None else (cache["cross_k"], cache["cross_v"])
    if cross_kv is not None:
        a, _ = L.apply_attention(
            p["cross"], x, cfg=cfg, cache=None, pos=pos, mode=mode,
            rope_theta=None, cross_kv=cross_kv)
        x = x + a
    x = x + L.apply_mlp(p["mlp"], x, cfg=cfg)
    nc = None
    if cache is not None:
        nc = dict(cache)
        nc["self"] = nself
    return x, nc


def _enc_block_init(key, cfg, max_seq):
    k1, k2 = jax.random.split(key)
    return {"attn": L.init_attention(k1, cfg, max_seq), "mlp": L.init_mlp(k2, cfg, gated=False)}


def _enc_block_apply(p, x, *, cfg):
    a, _ = L.apply_attention(p["attn"], x, cfg=cfg, cache=None, pos=None,
                             mode="encode", rope_theta=None)
    x = x + a
    return x + L.apply_mlp(p["mlp"], x, cfg=cfg)


def _ed_init(key, cfg: ModelConfig, max_seq: int):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    enc_keys = jax.random.split(k3, cfg.encoder.n_layers)
    return {
        "embed": T.init_embed(k1, cfg, max_seq),
        "stack": jax.vmap(lambda k: _xattn_init(k, cfg, max_seq))(
            jax.random.split(k2, cfg.n_layers)
        ),
        "enc": jax.vmap(lambda k: _enc_block_init(k, cfg, max_seq))(enc_keys),
        "enc_norm": L.init_norm(cfg, cfg.d_model),
    }


def _encode(p, frames, cfg: ModelConfig):
    x = frames.astype(L.ACT_DTYPE)
    x = x + _sinusoid(x.shape[1], cfg.d_model)[None].astype(L.ACT_DTYPE)
    x = constrain(x, "batch", "frames", "embed")

    def body(carry, p_i):
        return _enc_block_apply(p_i, carry, cfg=cfg), 0

    x, _ = lax.scan(body, x, p["enc"])
    return L.apply_norm(p["enc_norm"], x, cfg)


def _cross_kv(p_stack, enc_out, cfg: ModelConfig):
    """Precompute per-layer cross K/V from encoder output (scanned)."""
    hd = cfg.resolved_head_dim

    def body(_, p_i):
        k = L.dense(p_i["cross"]["wk"], enc_out).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, hd)
        v = L.dense(p_i["cross"]["wv"], enc_out).reshape(
            enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, hd)
        return 0, (k, v)

    _, (ks, vs) = lax.scan(body, 0, p_stack)
    return ks, vs  # [L, B, F, Hkv, hd]


def _ed_forward_train(p, batch, cfg: ModelConfig):
    enc_out = _encode(p, batch["frames"], cfg)
    x = T.embed_tokens(p["embed"], batch["tokens"], cfg)
    ks, vs = _cross_kv(p["stack"], enc_out, cfg)

    def body(carry, xs):
        p_i, k_i, v_i = xs
        a, _ = L.apply_attention(p_i["self"], carry, cfg=cfg, cache=None,
                                 pos=None, mode="train", rope_theta=None)
        h = carry + a
        a, _ = L.apply_attention(p_i["cross"], h, cfg=cfg, cache=None, pos=None,
                                 mode="train", rope_theta=None, cross_kv=(k_i, v_i))
        h = h + a
        h = h + L.apply_mlp(p_i["mlp"], h, cfg=cfg)
        return h, 0

    x, _ = lax.scan(body, x, (p["stack"], ks, vs))
    return T.logits_head(p["embed"], x, cfg)


def _ed_init_cache(cfg: ModelConfig, batch: int, max_seq: int):
    unit = {
        "self": L.init_attn_cache(cfg, batch, max_seq),
        "cross_k": jnp.zeros(
            (batch, cfg.encoder.n_frames, cfg.n_kv_heads, cfg.resolved_head_dim),
            L.ACT_DTYPE),
        "cross_v": jnp.zeros(
            (batch, cfg.encoder.n_frames, cfg.n_kv_heads, cfg.resolved_head_dim),
            L.ACT_DTYPE),
    }
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), unit)


def _ed_prefill(p, batch, cfg: ModelConfig, cache):
    enc_out = _encode(p, batch["frames"], cfg)
    ks, vs = _cross_kv(p["stack"], enc_out, cfg)
    x = T.embed_tokens(p["embed"], batch["tokens"], cfg)

    def body(carry, xs):
        p_i, c_i, k_i, v_i = xs
        c_i = dict(c_i)
        c_i["cross_k"], c_i["cross_v"] = k_i, v_i
        h, nc = _xattn_apply(p_i, carry, cfg=cfg, cache=c_i, pos=None, mode="prefill")
        return h, nc

    x, new_cache = lax.scan(body, x, (p["stack"], cache, ks, vs))
    logits = T.logits_head(p["embed"], x[:, -1:], cfg)
    return logits[:, 0], new_cache


def _ed_decode_hidden(p, tok, cache, pos, cfg: ModelConfig, ft=None):
    if ft is not None:
        raise NotImplementedError(
            "in-model protected GEMMs are decoder-only; the enc-dec family "
            "supports ft_scope='head' (engine-side entangled head) only")
    x = T.embed_tokens(p["embed"], tok, cfg, pos=pos)

    def body(carry, xs):
        p_i, c_i = xs
        h, nc = _xattn_apply(p_i, carry, cfg=cfg, cache=c_i, pos=pos, mode="decode")
        return h, nc

    x, new_cache = lax.scan(body, x, (p["stack"], cache))
    return T.final_hidden(p["embed"], x, cfg)[:, 0], new_cache


def _ed_decode(p, tok, cache, pos, cfg: ModelConfig):
    h, new_cache = _ed_decode_hidden(p, tok, cache, pos, cfg)
    logits = T.head_project(p["embed"], h[:, None], cfg)
    return logits[:, 0], new_cache


def _ed_prefill_chunk(p, tokens, cfg: ModelConfig, cache, *, pos0: int = 0,
                      lengths=None, ft=None):
    raise NotImplementedError(
        "chunked/bucketed prefill is decoder-only; enc-dec prefill needs "
        "frames and runs whole-prompt (_ed_prefill)")


def _ed_prefill_packed(p, tokens, cfg: ModelConfig, cache, *, pos0,
                       lengths=None, ft=None):
    raise NotImplementedError(
        "token-packed prefill is decoder-only; enc-dec prefill needs "
        "frames and runs whole-prompt (_ed_prefill)")


ENCDEC_MODEL = Model(
    init=_ed_init,
    forward_train=_ed_forward_train,
    prefill=_ed_prefill,
    prefill_chunk=_ed_prefill_chunk,
    prefill_packed=_ed_prefill_packed,
    decode_step=_ed_decode,
    decode_hidden=_ed_decode_hidden,
    head_project=_head_project,
    head_weights=_head_weights,
    init_cache=_ed_init_cache,
)


def get_model(cfg: ModelConfig) -> Model:
    return ENCDEC_MODEL if cfg.family == "encdec" else DECODER_MODEL


# ------------------------------------------------------------------- loss ----

def lm_loss(logits, batch, cfg: ModelConfig):
    """Next-token CE (+ 0.3-weighted MTP t+2 CE for DeepSeek-V3)."""
    if isinstance(logits, tuple):
        main, mtp = logits
    else:
        main, mtp = logits, None
    tokens = batch["tokens"]
    full_mask = batch.get("loss_mask", jnp.ones_like(tokens))
    if cfg.loss_impl == "streamed":
        from repro.models.loss import streamed_lm_ce

        loss = streamed_lm_ce(main, tokens, full_mask, shift=1)
        if mtp is not None:
            loss = loss + 0.3 * streamed_lm_ce(mtp, tokens, full_mask, shift=2)
        return loss
    mask = full_mask[:, 1:].astype(jnp.float32)
    lp = jax.nn.log_softmax(main[:, :-1].astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(lp, tokens[:, 1:, None], axis=-1)[..., 0]
    loss = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    if mtp is not None:
        m2 = full_mask[:, 2:].astype(jnp.float32)
        lp2 = jax.nn.log_softmax(mtp[:, :-2].astype(jnp.float32), axis=-1)
        ll2 = jnp.take_along_axis(lp2, tokens[:, 2:, None], axis=-1)[..., 0]
        loss = loss + 0.3 * (-jnp.sum(ll2 * m2) / jnp.maximum(jnp.sum(m2), 1.0))
    return loss
