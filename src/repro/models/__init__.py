from repro.models.api import Model, get_model, lm_loss

__all__ = ["Model", "get_model", "lm_loss"]
