"""Fused cross-entropy: no f32 [tokens, vocab] softmax residuals.

The naive CE (jax.nn.log_softmax then gather) makes autodiff SAVE the f32
log-probabilities for backward — at train_4k/128k-vocab the single largest
activation in the step. This custom-VJP version saves only the [N] logsumexp
and recomputes `(softmax - onehot)` in backward as one fused expression, so
forward adds ~nothing (max/sumexp fuse into reductions) and backward's only
large tensor is the unavoidable dlogits itself.

All expressions reduce/broadcast along the vocab axis directly — they respect
a vocab-sharded logits layout under GSPMD (an earlier vocab-chunk-scanned
variant forced logits replication on the multi-pod mesh: scanning over a
sharded axis gathers; see EXPERIMENTS.md §Perf cell 3 iteration 2b).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _stats(logits2d: jax.Array, targets: jax.Array):
    l32 = logits2d.astype(jnp.float32)
    m = jnp.max(l32, axis=-1)
    s = jnp.sum(jnp.exp(l32 - m[:, None]), axis=-1)
    lse = m + jnp.log(jnp.maximum(s, 1e-30))
    tl = jnp.take_along_axis(l32, targets[:, None], axis=-1)[:, 0]
    return lse, tl


@jax.custom_vjp
def streamed_ce(logits2d, targets, mask):
    """Mean masked CE over [N, V] logits (f32 math, bf16-safe inputs)."""
    lse, tl = _stats(logits2d, targets)
    return jnp.sum((lse - tl) * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _ce_fwd(logits2d, targets, mask):
    lse, tl = _stats(logits2d, targets)
    loss = jnp.sum((lse - tl) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, (logits2d, targets, mask, lse)


def _ce_bwd(res, g):
    logits2d, targets, mask, lse = res
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    coef = (g * mask / denom).astype(jnp.float32)  # [N]
    p = jnp.exp(logits2d.astype(jnp.float32) - lse[:, None])
    onehot = targets[:, None] == jnp.arange(logits2d.shape[1])[None]
    dlogits = (coef[:, None] * (p - onehot.astype(jnp.float32)))
    return dlogits.astype(logits2d.dtype), None, None


streamed_ce.defvjp(_ce_fwd, _ce_bwd)


def streamed_lm_ce(logits, tokens, mask, chunk: int = 0, shift: int = 1):
    """CE over [B, T, V] logits where position t predicts token t+shift.
    (``chunk`` retained for API compatibility; fusion makes it unnecessary.)"""
    del chunk
    B, T, V = logits.shape
    l2 = logits[:, :-shift].reshape(-1, V)
    t2 = tokens[:, shift:].reshape(-1)
    m2 = mask[:, shift:].reshape(-1).astype(jnp.float32)
    return streamed_ce(l2, t2, m2)
