"""AdamW with optional low-precision moments (distributed-optimization trick:
bf16 m/v halves optimizer-state HBM — the difference between DeepSeek-V3
fitting 512 chips or not; see EXPERIMENTS.md §Dry-run)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: Optional[str] = None  # None=f32 | 'bfloat16'
    # muP-style width transfer: ``lr`` is tuned at ``mup_base_width``; the
    # effective rate scales by base/d_model so narrow smoke models and wide
    # production models share one tuning (None disables scaling)
    mup_base_width: Optional[int] = 2048


def effective_lr_config(cfg: AdamWConfig, d_model: int) -> AdamWConfig:
    """Width-transferred copy of ``cfg`` for a model of width ``d_model``."""
    if not cfg.mup_base_width or d_model <= 0 or d_model == cfg.mup_base_width:
        return cfg
    return dataclasses.replace(cfg, lr=cfg.lr * cfg.mup_base_width / d_model)


def schedule(cfg: AdamWConfig, step):
    # step+1: the first optimizer step must not be a no-op (lr=0)
    warm = jnp.minimum((step + 1) / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init(params, cfg: AdamWConfig):
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    z = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}


def update(grads, opt_state, params, step, cfg: AdamWConfig):
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = step + 1

    def m_upd(g, m):
        return (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype)

    def v_upd(g, v):
        return (b2 * v.astype(jnp.float32)
                + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(v.dtype)

    m_new = jax.tree.map(m_upd, grads, opt_state["m"])
    v_new = jax.tree.map(v_upd, grads, opt_state["v"])

    def p_upd(p, m, v):
        mhat = m.astype(jnp.float32) / (1 - b1**t)
        vhat = v.astype(jnp.float32) / (1 - b2**t)
        step_ = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype)

    params_new = jax.tree.map(p_upd, params, m_new, v_new)
    return params_new, {"m": m_new, "v": v_new}
