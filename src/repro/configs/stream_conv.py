"""The paper's own workload: M integer streams convolved with kernel g.

This is the configuration behind paper Fig. 2 / Sec. V (Intel IPP conv of
M in {3, 8} streams, N_in = 1e6 samples, kernel sizes 100..4500) — kept as a
first-class "architecture" so the benchmark harness and FT engine exercise
the exact published experiment.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StreamConvConfig:
    name: str = "stream-conv"
    M: int = 3
    w: int = 32
    n_in: int = 1_000_000
    kernel_sizes: tuple[int, ...] = (100, 500, 1000, 2000, 4500)


CONFIG = StreamConvConfig()


def smoke_config() -> StreamConvConfig:
    return StreamConvConfig(name="stream-conv-smoke", n_in=4096, kernel_sizes=(16, 64))
