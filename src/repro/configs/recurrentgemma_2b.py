"""RecurrentGemma-2B — RG-LRU recurrent blocks + local attention, 1:2 ratio.

[arXiv:2402.19427; hf] 26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000,
local attention window 2048, head_dim=256, pattern (rglru, rglru, local_attn).
"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    local_window=2048,
    rope_theta=1e4,
    tie_embeddings=True,  # Gemma family ties embed/head (2.7B, not 3.6B)
    rglru=RGLRUConfig(lru_width=2560, d_conv=4, c=8.0),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        n_layers=3,  # one full (rglru, rglru, local_attn) unit
        d_model=64,
        n_heads=2,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=256,
        head_dim=32,
        local_window=16,
        tie_embeddings=True,
        rglru=RGLRUConfig(lru_width=64, d_conv=4, c=8.0),
    )
