"""InternVL2-2B — InternViT frontend (stub) + InternLM2-1.8B backbone.

[arXiv:2404.16821; hf] 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
Vision frontend is a STUB per task spec: input_specs() provides precomputed
patch embeddings; the backbone transformer is fully modeled.
"""
from repro.configs.base import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    head_dim=128,
    rope_theta=1e6,
    vision=VisionConfig(n_patches=256),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        rope_theta=1e6,
        vision=VisionConfig(n_patches=8),
    )
