"""Whisper-small — encoder-decoder; conv frontend is a STUB (task spec):
input_specs() provides precomputed frame embeddings [B, 1500, d_model].

[arXiv:2212.04356; unverified] 12L (x2 enc/dec) d_model=768 12H d_ff=3072
vocab=51865, LayerNorm, learned positions (no RoPE).
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,  # decoder layers; encoder in EncoderConfig
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    norm_kind="layernorm",
    norm_eps=1e-5,
    encoder=EncoderConfig(n_layers=12, n_frames=1500),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke",
        family="encdec",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        norm_kind="layernorm",
        norm_eps=1e-5,
        encoder=EncoderConfig(n_layers=2, n_frames=16),
    )
