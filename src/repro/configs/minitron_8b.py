"""Minitron-8B — width-pruned Nemotron-4.

[arXiv:2407.14679; hf] 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    head_dim=128,
    rope_theta=1e4,
    mlp_gated=False,  # Nemotron squared-ReLU MLP (gated would be ~9.9B)
    mlp_act="relu2",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-8b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        mlp_gated=False,
        mlp_act="relu2",
    )
