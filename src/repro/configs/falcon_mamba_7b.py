"""Falcon-Mamba-7B — attention-free Mamba-1 SSM.

[arXiv:2410.05355; unverified] 64L d_model=4096 vocab=65024 ssm_state=16,
d_conv=4, expand=2 (d_inner=8192).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,  # attn-free
    n_kv_heads=1,
    d_ff=0,
    vocab_size=65024,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=1,
        n_kv_heads=1,
        d_ff=0,
        vocab_size=256,
        ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
    )
