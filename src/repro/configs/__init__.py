from repro.configs.base import (
    EncoderConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    SHAPE_CELLS,
    SSMConfig,
    ShapeCell,
    VisionConfig,
    cells_for,
)
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config

__all__ = [
    "ARCH_IDS",
    "EncoderConfig",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "RGLRUConfig",
    "SHAPE_CELLS",
    "SSMConfig",
    "ShapeCell",
    "VisionConfig",
    "cells_for",
    "get_config",
    "get_smoke_config",
]
