"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_MODULES: Dict[str, str] = {
    "internvl2-2b": "repro.configs.internvl2_2b",
    "minitron-8b": "repro.configs.minitron_8b",
    "granite-20b": "repro.configs.granite_20b",
    "qwen2-7b": "repro.configs.qwen2_7b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "falcon-mamba-7b": "repro.configs.falcon_mamba_7b",
    "whisper-small": "repro.configs.whisper_small",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).smoke_config()
