"""Architecture config schema covering all 10 assigned architecture families.

One frozen dataclass drives model construction, sharding rules, input specs
and the dry-run. Every assigned architecture gets a module in this package
exporting ``CONFIG`` (exact published hyperparameters) and ``smoke_config()``
(reduced same-family variant for CPU tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0
    gating: str = "softmax"  # softmax (v2) | sigmoid (v3)
    first_dense_layers: int = 0  # leading layers that use a dense FFN
    capacity_factor: float = 1.25  # expert buffer slack; >= n_experts/top_k
    #   makes dispatch dropless (exactness tests use that)
    dispatch: str = "grouped"  # grouped (shard-local + EP all-to-all, §Perf
    #   iteration 1) | global_sort (pre-iteration baseline)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 = full-rank Q projection (v2-lite)
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-1 selective SSM."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 = ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma RG-LRU recurrent block."""

    lru_width: int = 0  # 0 = d_model
    d_conv: int = 4
    c: float = 8.0


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (Whisper). Frontend is a stub: inputs are
    precomputed frame embeddings (task spec)."""

    n_layers: int = 12
    n_frames: int = 1500


@dataclasses.dataclass(frozen=True)
class VisionConfig:
    """VLM frontend stub: inputs are precomputed patch embeddings."""

    n_patches: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 = d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    norm_kind: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    local_window: int = 0  # >0: sliding-window attention (recurrentgemma)
    attn_pattern: Tuple[str, ...] = ()  # per-unit block names; () = (attn,)*
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    encoder: Optional[EncoderConfig] = None
    vision: Optional[VisionConfig] = None
    mtp: bool = False  # DeepSeek-V3 multi-token-prediction head
    remat: str = "none"  # none | full | dots — activation checkpointing of
    #   each scanned unit body (train memory vs recompute trade)
    norm_f32: bool = True  # True: f32-materialized normalize (faithful
    #   default); False: f32 stats but bf16 elementwise apply (§Perf lever —
    #   removes one f32 [B,T,D] round-trip per norm on memory-bound cells)
    loss_impl: str = "naive"  # naive | streamed — streamed CE scans vocab
    #   chunks, avoiding f32 [tokens, vocab] softmax buffers (§Perf lever)
    mlp_gated: Optional[bool] = None  # None = by family (rmsnorm -> gated)
    mlp_act: str = "silu"  # silu | gelu | relu2 (Nemotron squared ReLU)
    mla_absorb: bool = False  # decode-time absorbed MLA projections: score
    #   cached latents directly (O(S·r) instead of O(S·r·d_head) per head) —
    #   §Perf lever for the DeepSeek decode cells; False = paper-faithful
    #   naive up-projection

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    def layer_pattern(self) -> Sequence[tuple[Tuple[str, ...], int]]:
        """[(unit_block_names, repeats)] — homogeneous units are scanned.

        Every unit repetition is compiled ONCE (jax.lax.scan over stacked
        params), keeping HLO size O(#unit kinds), not O(#layers) — required
        to compile 61-layer configs in the dry-run.
        """
        if self.family == "ssm":
            return [(("mamba",), self.n_layers)]
        if self.family == "hybrid":
            # RecurrentGemma 1 local-attn : 2 recurrent, pattern (rg, rg, att)
            n_units, rem = divmod(self.n_layers, 3)
            pat: list[tuple[Tuple[str, ...], int]] = []
            if n_units:
                pat.append((("rglru", "rglru", "local_attn"), n_units))
            if rem:
                pat.append((tuple(["rglru"] * rem), 1))
            return pat
        if self.family == "moe":
            assert self.moe is not None
            fd = self.moe.first_dense_layers
            pat = []
            if fd:
                pat.append((("attn_dense",), fd))
            pat.append((("attn_moe",), self.n_layers - fd))
            return pat
        # dense / vlm / encdec decoder
        return [(("attn_dense",), self.n_layers)]

    @property
    def is_subquadratic(self) -> bool:
        """True if decode state does not grow linearly with full context —
        the long_500k eligibility rule (see DESIGN.md §6)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPE_CELLS = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def cells_for(cfg: ModelConfig) -> Sequence[ShapeCell]:
    """Shape cells applicable to an architecture (DESIGN.md §6)."""
    cells = []
    for cell in SHAPE_CELLS:
        if cell.name == "long_500k" and not cfg.is_subquadratic:
            continue  # full-attention archs: 512k dense decode is skipped
        cells.append(cell)
    return cells
