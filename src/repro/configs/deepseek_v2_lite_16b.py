"""DeepSeek-V2-Lite (16B total / 2.4B active) — MLA + MoE.

[arXiv:2405.04434; hf] 27L d_model=2048 16H d_ff_expert=1408 vocab=102400,
MLA kv_lora=512 (no q-lora), 2 shared + 64 routed experts top-6, first layer
dense (d_ff=10944), softmax gating.

Note: the assignment line reads "MoE 64e top-6 ... 2 shared+160 routed"; 160
routed is full V2 — the V2-LITE checkpoint has 64 routed experts, which the
"64e top-6" prefix (and HF config) confirms, so 64 is used.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,  # dense first layer
    vocab_size=102400,
    rope_theta=1e4,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        n_shared=2,
        d_ff_expert=1408,
        gating="softmax",
        first_dense_layers=1,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=0,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=160,
        vocab_size=256,
        moe=MoEConfig(
            n_experts=8,
            top_k=2,
            n_shared=1,
            d_ff_expert=32,
            gating="softmax",
            first_dense_layers=1,
        ),
        mla=MLAConfig(
            kv_lora_rank=32,
            q_lora_rank=0,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        ),
    )
