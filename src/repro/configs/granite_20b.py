"""Granite-20B-Code — llama-style architecture with MQA (kv=1).

[arXiv:2405.04324; hf] 52L d_model=6144 48H (GQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    head_dim=128,
    rope_theta=1e4,
    mlp_gated=False,  # classic 4x GPT MLP (gated would be ~28B, not 20B)
    mlp_act="gelu",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-20b-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,  # preserve the MQA shape
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        mlp_gated=False,
        mlp_act="gelu",
    )
