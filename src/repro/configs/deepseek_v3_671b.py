"""DeepSeek-V3 (671B total / 37B active) — MLA + MoE + MTP.

[arXiv:2412.19437; hf] 61L d_model=7168 128H d_ff_expert=2048 vocab=129280,
MLA kv_lora=512 q_lora=1536, 1 shared + 256 routed experts top-8, first 3
layers dense (d_ff=18432), sigmoid gating, multi-token-prediction module.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,  # dense first layers
    vocab_size=129280,
    rope_theta=1e4,
    mtp=True,
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        n_shared=1,
        d_ff_expert=2048,
        gating="sigmoid",
        first_dense_layers=3,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        q_lora_rank=1536,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-smoke",
        family="moe",
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=160,
        vocab_size=256,
        mtp=True,
        moe=MoEConfig(
            n_experts=8,
            top_k=2,
            n_shared=1,
            d_ff_expert=32,
            gating="sigmoid",
            first_dense_layers=1,
        ),
        mla=MLAConfig(
            kv_lora_rank=32,
            q_lora_rank=48,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        ),
    )
