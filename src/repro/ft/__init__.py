"""Unified protected-GEMM subsystem: the paper's numerical entanglement as
a reusable wrapper around EVERY hot-path projection.

v2 architecture — compiled at the top, pluggable at the bottom:

  quantize.py   the int8 policy — per-tensor weight quantization (with
                the stacked per-layer/per-expert form the startup hoist
                uses) + the eq. (13) depth-aware activation budget, and
                the TRACE_STATS counter proving no weight-quantization op
                enters a traced step
  registry.py   PlanRegistry: (site, shape, M, backend) ->
                :class:`ProtectionPlan` (shared EntanglePlan + per-shape
                block sizes; ``grouped`` marks MoE per-expert sites); the
                protected shape census warm_autotune iterates
  plans.py      the ahead-of-time layer: ``compile_plans`` freezes the
                startup census into an immutable :class:`CompiledPlans`,
                ``prepare_params`` quantizes every protected site's
                weights ONCE into ``q8`` entries inside the params pytree
                (per layer, per expert — sliced by the layer scan like
                the float masters)
  protected.py  protected_matmul / protected_matmul_grouped — flatten,
                quantize, round-robin group, fused entangled kernel
                (backend-pluggable via kernels/ops), roll-forward —
                ProtectedLinear (a thin executor over one compiled plan)
                and FTContext, the scope-aware object threaded through
                models/api -> transformer.apply_stack -> layers
  heads.py      the serving head entries (ft_logits / _decode / _prefill,
                quantize_head) — the ONLY surface for the protected head
                (the old ``repro.serve.ft_logits`` shim is removed;
                ``repro.serve`` re-exports these names directly)

Scope model (``ServeConfig.ft_scope``): ``"head"`` protects the vocab
projection, ``"qkv"`` adds the mixer input projections (attention Q/K/V,
MLA q/kv_a, Mamba in_proj, RG-LRU in_x/in_gate), ``"mlp"`` the FFN
projections (gate/up/down and the MoE router), ``"out"`` the mixer output
projections (attention/MLA wo, Mamba out_proj, RG-LRU out), ``"moe"`` the
MoE per-expert GEMMs (grouped entangled kernel), and ``"all"`` — since v2
— genuinely everything. At every scope, a single fail-stop injected into
any of the M request groups — during batched decode or chunked bucketed
admission — rolls forward in-kernel with bit-identical tokens.

See ``repro/kernels/__init__.py`` ("how to protect a new GEMM") for the
recipe to add a site to the v2 plan-compile flow.
"""
from repro.ft.plans import (PROTECTED_WEIGHT_KEYS, CompiledPlans,
                            compile_plans, prepare_params)
from repro.ft.protected import (FTContext, ProtectedLinear, SCOPES,
                                entangled_chain, group_order,
                                protected_matmul, protected_matmul_grouped)
from repro.ft.quantize import (activation_budget, chain_budget,
                               quantize_acts, quantize_weight,
                               quantize_weight_stacked)
from repro.ft.registry import (PlanEntry, PlanRegistry, ProtectionPlan,
                               default_blocks, group_rows)

__all__ = [
    "CompiledPlans",
    "FTContext",
    "PROTECTED_WEIGHT_KEYS",
    "PlanEntry",
    "PlanRegistry",
    "ProtectedLinear",
    "ProtectionPlan",
    "SCOPES",
    "activation_budget",
    "chain_budget",
    "compile_plans",
    "entangled_chain",
    "default_blocks",
    "group_order",
    "group_rows",
    "prepare_params",
    "protected_matmul",
    "protected_matmul_grouped",
    "quantize_acts",
    "quantize_weight",
    "quantize_weight_stacked",
]
