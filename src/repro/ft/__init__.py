"""Unified protected-GEMM subsystem: the paper's numerical entanglement as
a reusable wrapper around EVERY hot-path projection.

Until PR 4 only the serving head GEMM ran entangled
(``serve/ft_logits.py``); the far larger prefill-chunk QKV/MLP admission
GEMMs were unprotected — the exact gap checksum-style ABFT pays 9-14x more
to close. This package extracts that one-off wiring into a subsystem any
GEMM can opt into:

  quantize.py   the int8 policy — per-tensor weight quantization + the
                eq. (13) depth-aware activation budget
  registry.py   PlanRegistry: (site, shape, M, backend) -> PlanEntry
                (shared EntanglePlan + per-shape block sizes); the
                protected shape census warm_autotune iterates
  protected.py  protected_matmul / ProtectedLinear — flatten, quantize,
                round-robin group, fused entangled kernel, roll-forward —
                and FTContext, the scope-aware object threaded through
                models/api -> transformer.apply_stack -> layers

Scope model (``ServeConfig.ft_scope``): ``"head"`` protects the vocab
projection (PR 2/3 behavior), ``"qkv"`` adds the mixer input projections
(attention Q/K/V, MLA q/kv_a, Mamba in_proj, RG-LRU in_x/in_gate),
``"mlp"`` adds the FFN projections (gate/up/down and the MoE router),
``"all"`` protects everything. At every scope, a single fail-stop injected
into any of the M request groups — during batched decode or chunked
bucketed admission — rolls forward in-kernel with bit-identical tokens.

See ``repro/kernels/__init__.py`` ("how to protect a new GEMM") for the
recipe to add a site.
"""
from repro.ft.protected import (FTContext, ProtectedLinear, SCOPES,
                                group_order, protected_matmul)
from repro.ft.quantize import (activation_budget, quantize_acts,
                               quantize_weight)
from repro.ft.registry import (PlanEntry, PlanRegistry, default_blocks,
                               group_rows)

__all__ = [
    "FTContext",
    "PlanEntry",
    "PlanRegistry",
    "ProtectedLinear",
    "SCOPES",
    "activation_budget",
    "default_blocks",
    "group_order",
    "group_rows",
    "protected_matmul",
    "quantize_acts",
    "quantize_weight",
]
