"""ProtectedLinear — the paper's entangled roll-forward wrapped around any
hot-path GEMM.

:func:`protected_matmul` is the one code path every plain protected
projection runs through: float activations of ANY leading shape are
flattened to rows, quantized onto the plan's eq. (13) integer grid
(:mod:`repro.ft.quantize` — PER-ROW scales, so no row's grid depends on
its batch neighbours), padded with zero rows to a multiple of M
(exact — zeros entangle to zeros and cannot perturb any other stream's
accumulator), mapped round-robin onto the
M entangled streams (row -> group = row % M, the serving engine's
slot -> group contract), and pushed through the fused kernel behind
:mod:`repro.kernels.ops` (backend-pluggable: Pallas TPU, interpret CPU,
reference, or a registered port): entangle-on-load, int GEMM, extraction
in the flush epilogue — one kernel call, zero codec HBM sweeps. A
fail-stopped group's accumulator is statically excluded from the in-kernel
extraction (``failed=r``), so its outputs are rolled forward from the
other M-1 streams and the recovered integers are bit-identical to a
healthy run.

:func:`protected_matmul_grouped` is the grouped (per-expert) twin for MoE:
activations ``[..., E, C, K]`` against per-expert weights ``[E, K, N]``
run as ONE grouped entangled kernel call — rows map round-robin onto the M
streams *within each expert*, so recovery holds independently and
identically for every expert.

:class:`FTContext` is the object threaded through the model
(``models/api.py -> transformer.apply_stack -> layers``): it decides which
site categories the configured ``ft_scope`` protects, resolves each call
site's :class:`~repro.ft.registry.ProtectionPlan` — ahead-of-time from the
immutable :class:`~repro.ft.plans.CompiledPlans` the engine builds at
startup, or lazily from the registry for library users — and carries the
static ``failed_group`` of the current traced program.  Site names are
``"<category>.<proj>"`` — categories:

  ``head``  the vocab projection (always protected when FT is on)
  ``qkv``   mixer input projections: attention Q/K/V, MLA q/kv_a,
            Mamba in_proj, RG-LRU in_x/in_gate
  ``mlp``   FFN projections: MLP gate/up/down (dense and MoE-shared) and
            the MoE router
  ``out``   mixer output projections: attention/MLA wo, Mamba out_proj,
            RG-LRU out
  ``moe``   MoE per-expert gate/up/down GEMMs (the grouped kernel)

``ft_scope`` widens protection cumulatively: ``"head"`` | ``"qkv"`` |
``"mlp"`` | ``"out"`` | ``"moe"`` (each includes the head) | ``"all"`` —
which, since v2, genuinely covers every hot-path GEMM.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.entangle import disentangle as core_disentangle
from repro.core.entangle import entangle as core_entangle
from repro.core.failstop import GARBAGE
from repro.core.plan import EntanglePlan
from repro.ft.quantize import (chain_budget, quantize_acts, quantize_weight,
                               quantize_weight_stacked)
from repro.kernels.codec import unpack_int8
from repro.ft.registry import PlanRegistry, ProtectionPlan, group_rows

# scope -> protected site categories (cumulative; head is always in)
SCOPES: dict[str, frozenset] = {
    "head": frozenset({"head"}),
    "qkv": frozenset({"head", "qkv"}),
    "mlp": frozenset({"head", "mlp"}),
    "out": frozenset({"head", "out"}),
    "moe": frozenset({"head", "moe"}),
    "all": frozenset({"head", "qkv", "mlp", "out", "moe"}),
}

# float weight, or (int8-range int32 weights, scale) pre-quantized at startup
Weight = Union[jax.Array, tuple]


def group_order(R: int, M: int) -> tuple[np.ndarray, np.ndarray]:
    """Static permutation realizing round-robin grouping (row -> group =
    row % M) on top of a contiguous [M, R/M] stream layout.

    ``order[g * R//M + j] = j * M + g`` — position p of the permuted batch
    holds row ``order[p]``; ``inv`` undoes it (``inv[row]`` = position of
    that row's output in the permuted result). Round-robin keeps every
    entangled group populated whenever >= M rows are live, so a fail-stop
    in any group is recoverable from M-1 *other* live groups.
    """
    assert R % M == 0, f"row count {R} must split into M={M} groups"
    order = np.arange(R, dtype=np.int32).reshape(R // M, M).T.reshape(R)
    inv = np.argsort(order).astype(np.int32)
    return order, inv


def _split_weight(w: Weight):
    """(wq, w_scale) from a float master (in-graph quantization — the
    legacy/library path) or a pre-quantized (wq, scale) pair (the v2
    prepared-params path; no quantization op enters the trace)."""
    if isinstance(w, tuple):
        return w
    return quantize_weight(w)


def _is_packed(wq: jax.Array, K: int, axis: int = -2) -> bool:
    """Packedness of a pre-quantized weight, from its contraction-axis
    length: the packed copy carries ceil(K/4) int32 words for K int8
    lanes. Every protected K is >= 2, so the lengths can never collide."""
    return wq.shape[axis] != K


def _unpacked_f32(wq: jax.Array, K: int, axis: int) -> jax.Array:
    """Float view of a maybe-packed weight for the census einsums (the
    abstract traces only need shapes; a float master passes through)."""
    if _is_packed(wq, K, axis=axis):
        wq = unpack_int8(wq, axis=axis, n=K)
    return wq.astype(jnp.float32)


def protected_matmul(
    x: jax.Array,  # [..., K] float activations
    w: Weight,  # [K, N] float weights, or (wq, w_scale) pre-quantized
    *,
    plan: EntanglePlan,
    failed_group: Optional[int] = None,
    use_pallas: bool = True,
    fuse_epilogue: bool = True,
    blocks=None,
    contiguous: bool = False,
    interpret=None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Entangled int8 GEMM with in-kernel fail-stop roll-forward.

    Returns dequantized float32 outputs ``[..., N]``. ``contiguous=True``
    keeps the caller's row order as the [M, R/M] group layout (the library
    :func:`repro.ft.heads.ft_logits` contract); the default maps rows
    round-robin onto groups. ``fuse_epilogue=False`` keeps the separate
    disentangle pass for callers that must inject/persist entangled
    outputs; ``use_pallas=False`` is the XLA reference path; ``backend``
    routes to a registered kernel backend (default: the platform rule).
    """
    wq, w_scale = _split_weight(w)
    lead, K = x.shape[:-1], x.shape[-1]
    N = wq.shape[1]
    packed = _is_packed(wq, K)
    R = int(np.prod(lead, dtype=np.int64)) if lead else 1
    M = plan.M

    xf = x.reshape(R, K).astype(jnp.float32)
    xq, a_scale = quantize_acts(xf, plan, K)
    pad = (-R) % M
    if pad:
        xq = jnp.concatenate([xq, jnp.zeros((pad, K), jnp.int32)], axis=0)
    Rp = R + pad
    if contiguous:
        inv = None
        xg = xq.reshape(M, Rp // M, K)
    else:
        order, inv = group_order(Rp, M)
        xg = xq[order].reshape(M, Rp // M, K)

    from repro.kernels import ops as kops  # deferred: keeps core import-light

    if use_pallas and fuse_epilogue:
        # production hot path: entangle -> GEMM -> extract in ONE
        # kernel call; a fail-stopped group is rolled forward in-kernel by
        # statically excluding its accumulator from the extraction (the
        # algebra never reads it, so injecting garbage is equivalent)
        rec = kops.entangled_matmul(
            xg, wq, plan, fuse_epilogue=True, failed=failed_group,
            packed=packed, blocks=blocks, interpret=interpret,
            backend=backend)
    else:
        if use_pallas:
            delta = kops.entangled_matmul(xg, wq, plan, packed=packed,
                                          blocks=blocks, interpret=interpret,
                                          backend=backend)
        else:
            eps = core_entangle(xg, plan)
            wq_full = unpack_int8(wq, axis=0, n=K) if packed else wq
            delta = jnp.einsum("mbk,kn->mbn", eps, wq_full).astype(jnp.int32)
        if failed_group is not None:
            delta = delta.at[failed_group].set(GARBAGE)
        rec = core_disentangle(delta, plan, failed=failed_group)

    y = rec.reshape(Rp, N).astype(jnp.float32)
    if inv is not None:
        y = y[inv]
    y = y[:R] / (a_scale * w_scale)
    return y.reshape(*lead, N)


def protected_matmul_grouped(
    x: jax.Array,  # [..., E, C, K] float activations (C rows per expert)
    w: Weight,  # [E, K, N] float, or (wq [E, K, N], w_scale scalar or [E])
    *,
    plan: EntanglePlan,
    failed_group: Optional[int] = None,
    use_pallas: bool = True,
    fuse_epilogue: bool = True,
    blocks=None,
    interpret=None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Grouped (per-expert) entangled int8 GEMM — the MoE form.

    Expert e's C rows (times any leading batch axes) multiply expert e's
    [K, N] weights; all E GEMMs run in ONE grouped entangled kernel call
    (:func:`repro.kernels.ops.entangled_matmul_grouped`). Rows map
    round-robin onto the M streams within each expert, zero rows pad each
    expert's bucket to a multiple of M (exact), and ``failed_group``
    statically excludes that stream's accumulators from extraction — the
    roll-forward recovers every expert's outputs bit-identically at once.
    Returns dequantized float32 ``[..., E, C, N]``.
    """
    if isinstance(w, tuple):
        wq, w_scale = w
    else:
        q8 = quantize_weight_stacked(w)  # per-expert grids
        wq, w_scale = q8["w"], q8["scale"]
    E, N = wq.shape[0], wq.shape[2]
    K = x.shape[-1]
    packed = _is_packed(wq, K)
    lead = x.shape[:-3]
    C = x.shape[-2]
    assert x.shape[-3] == E, (x.shape, wq.shape)
    L = int(np.prod(lead, dtype=np.int64)) if lead else 1
    R = L * C  # rows per expert
    M = plan.M

    # [..., E, C, K] -> [E, R, K]: expert-major rows, leading axes folded
    xf = jnp.moveaxis(x.reshape(L, E, C, K), 1, 0).reshape(E, R, K)
    xf = xf.astype(jnp.float32)
    xq, a_scale = quantize_acts(xf, plan, K)
    pad = (-R) % M
    if pad:
        xq = jnp.concatenate(
            [xq, jnp.zeros((E, pad, K), jnp.int32)], axis=1)
    Rp = R + pad
    order, inv = group_order(Rp, M)
    # per-expert round-robin onto streams: [E, Rp, K] -> [M, E, Rp/M, K]
    xg = jnp.moveaxis(xq[:, order].reshape(E, M, Rp // M, K), 1, 0)

    from repro.kernels import ops as kops  # deferred: keeps core import-light

    if use_pallas and fuse_epilogue:
        rec = kops.entangled_matmul_grouped(
            xg, wq, plan, fuse_epilogue=True, failed=failed_group,
            packed=packed, blocks=blocks, interpret=interpret,
            backend=backend)
    else:
        if use_pallas:
            delta = kops.entangled_matmul_grouped(
                xg, wq, plan, packed=packed, blocks=blocks,
                interpret=interpret, backend=backend)
        else:
            eps = core_entangle(xg, plan)
            wq_full = unpack_int8(wq, axis=1, n=K) if packed else wq
            delta = jnp.einsum("meck,ekn->mecn", eps,
                               wq_full.astype(jnp.int32)).astype(jnp.int32)
        if failed_group is not None:
            delta = delta.at[failed_group].set(GARBAGE)
        rec = core_disentangle(delta, plan, failed=failed_group)

    y = jnp.moveaxis(rec, 0, 1).reshape(E, Rp, N).astype(jnp.float32)
    y = y[:, inv][:, :R]
    w_s = jnp.asarray(w_scale)
    scale = a_scale * (w_s if w_s.ndim == 0 else w_s[:, None, None])
    y = y / scale
    return jnp.moveaxis(y.reshape(E, L, C, N), 0, 1).reshape(*lead, E, C, N)


def entangled_chain(
    x: jax.Array,  # [..., K] float activations of the FIRST hop
    ws: list,  # per-hop weights: float [K_i, N_i] or (wq, w_scale) pairs
    *,
    plan: EntanglePlan,
    failed_group: Optional[int] = None,
    blocks=None,  # None, or one blocks policy per hop
    contiguous: bool = False,
    interpret=None,
    backend: Optional[str] = None,
) -> jax.Array:
    """Run N consecutive strictly-linear protected GEMMs WITHOUT leaving
    the entangled domain: one entangle, N GEMMs, one extract.

    Entanglement is linear over streams, so ``(E c) @ g = E (c @ g)`` —
    the first hop entangles on load and returns raw entangled accumulators
    (``fuse_epilogue=False``), every middle hop multiplies them through a
    plain per-stream GEMM (``'chain'``: no re-entangle, no extract), and
    the last hop extracts at its flush (``'chain_final'``). A fail-stopped
    stream's garbage propagates only within its own stream (each hop is
    per-stream), and the final extraction statically excludes it — the
    roll-forward is exact for any single failed stream failing at ANY
    point in the chain.

    The price is overflow headroom: the single extraction must absorb the
    whole chain's amplification, so the first hop quantizes onto
    :func:`~repro.ft.quantize.chain_budget`'s grid. When that budget is 0
    the chain is infeasible under this plan and the call falls back to
    per-hop :func:`protected_matmul` extraction (same protection, one
    extract per hop, requantizing between hops).

    Returns dequantized float32 outputs ``[..., N_last]``.
    """
    assert len(ws) >= 1
    split = [_split_weight(w) for w in ws]
    lead, K = x.shape[:-1], x.shape[-1]
    depths = [K]
    for wq, _ in split[:-1]:
        n = wq.shape[1]
        # a packed hop's true N is its column count (packing is along K
        # only), so the next hop's depth is simply shape[1]
        depths.append(n)
    budget = chain_budget(plan, depths)
    if budget < 1 or len(ws) == 1:
        # infeasible under this plan (or trivial): extract per hop
        y = x
        bl = blocks if blocks is not None else [None] * len(ws)
        for w, b in zip(ws, bl):
            y = protected_matmul(
                y, w, plan=plan, failed_group=failed_group, blocks=b,
                contiguous=contiguous, interpret=interpret, backend=backend)
        return y

    M = plan.M
    R = int(np.prod(lead, dtype=np.int64)) if lead else 1
    xf = x.reshape(R, K).astype(jnp.float32)
    xq, a_scale = quantize_acts(xf, plan, K, budget=budget)
    pad = (-R) % M
    if pad:
        xq = jnp.concatenate([xq, jnp.zeros((pad, K), jnp.int32)], axis=0)
    Rp = R + pad
    if contiguous:
        inv = None
        xg = xq.reshape(M, Rp // M, K)
    else:
        order, inv = group_order(Rp, M)
        xg = xq[order].reshape(M, Rp // M, K)

    from repro.kernels import ops as kops  # deferred: keeps core import-light

    bl = blocks if blocks is not None else [None] * len(ws)
    cur, depth = xg, K
    for i, (wq, _) in enumerate(split):
        if i == 0:
            mode = False  # entangle on load, keep entangled
        elif i == len(split) - 1:
            mode = "chain_final"  # extract at the last flush
        else:
            mode = "chain"
        cur = kops.entangled_matmul(
            cur, wq, plan, fuse_epilogue=mode, failed=failed_group,
            packed=_is_packed(wq, depth), blocks=bl[i],
            interpret=interpret, backend=backend)
        depth = wq.shape[1]

    N = split[-1][0].shape[1]
    y = cur.reshape(Rp, N).astype(jnp.float32)
    if inv is not None:
        y = y[inv]
    w_prod = 1.0
    for _, s in split:
        w_prod = w_prod * s
    y = y[:R] / (a_scale * w_prod)
    return y.reshape(*lead, N)


@dataclasses.dataclass(frozen=True)
class ProtectedLinear:
    """Thin executor over ONE compiled :class:`ProtectionPlan`.

    Since v2 this class holds no resolution logic: the plan (site, shape,
    entanglement parameters, block sizes, backend, grouped-ness) is fixed
    at construction — built ahead of time by
    :func:`repro.ft.plans.compile_plans` — and calling the executor just
    runs :func:`protected_matmul` / :func:`protected_matmul_grouped` with
    those static parameters. The serving engine holds one per protected
    (site, shape) implicitly through :class:`FTContext`; library users can
    bind one directly from a registry entry.
    """

    plan: ProtectionPlan
    use_pallas: bool = True
    interpret: Optional[bool] = None

    def __call__(self, x: jax.Array, w: Weight, *,
                 failed_group: Optional[int] = None,
                 contiguous: bool = False) -> jax.Array:
        p = self.plan
        if p.grouped:
            return protected_matmul_grouped(
                x, w, plan=p.plan, failed_group=failed_group,
                use_pallas=self.use_pallas, blocks=p.blocks,
                interpret=self.interpret, backend=p.backend)
        return protected_matmul(
            x, w, plan=p.plan, failed_group=failed_group,
            use_pallas=self.use_pallas, blocks=p.blocks,
            contiguous=contiguous, interpret=self.interpret,
            backend=p.backend)


def _backend() -> str:
    """Registry backend tag — the kernel-registry namespace this process
    resolves to (mirrors :func:`repro.kernels.ops.resolve_backend`)."""
    from repro.kernels import ops as kops

    return kops.resolve_backend()


@dataclasses.dataclass(frozen=True)
class FTContext:
    """Protection context threaded through the model forward pass.

    Created once by the serving engine at startup and specialized per
    traced program via :meth:`with_failed` (``failed_group`` is a static
    jit argument, so each injected-failure variant is its own compiled
    program sharing the same plans and autotune winners).

    ``plans`` (the v2 flow) is the immutable
    :class:`~repro.ft.plans.CompiledPlans` built by ``compile_plans`` at
    startup: every protected projection resolves there, and a lookup miss
    — a census gap — falls back to a lazily created registry entry with a
    warning instead of crashing the serving process. ``plans=None`` keeps
    the pure lazy-registry behavior for library users.

    ``census_only=True`` turns :meth:`matmul` / :meth:`matmul_grouped`
    into plain float einsums that merely REGISTER the call shape — the
    engine abstract-traces the forward pass with such a context to
    enumerate every protected shape without running (or compiling) any
    kernel; ``compile_plans`` then freezes exactly that census.
    """

    registry: PlanRegistry
    scope: str = "head"
    use_pallas: bool = True
    failed_group: Optional[int] = None
    census_only: bool = False
    plans: Optional[object] = None  # repro.ft.plans.CompiledPlans
    # share one quantize/permute codec pass across fanout site groups
    # (sites consuming the same activations — attention Q/K/V, MLP
    # gate/up, ...); census-only traces mark the groups either way, so
    # the compiled plans always expose what COULD chain
    chain: bool = True

    def __post_init__(self):
        if self.scope not in SCOPES:
            raise ValueError(
                f"unknown ft_scope {self.scope!r}; expected one of "
                f"{sorted(SCOPES)}")

    @property
    def plan(self) -> EntanglePlan:
        return self.registry.plan

    def protects(self, site: str) -> bool:
        return site.split(".", 1)[0] in SCOPES[self.scope]

    def with_failed(self, failed_group: Optional[int]) -> "FTContext":
        return dataclasses.replace(self, failed_group=failed_group)

    def with_plans(self, plans) -> "FTContext":
        return dataclasses.replace(self, plans=plans)

    def _resolve(self, site: str, rows: int, K: int, N: int,
                 groups: Optional[int] = None) -> ProtectionPlan:
        """AOT plan lookup with a loud-but-degrading lazy fallback."""
        if self.plans is not None:
            shape = self.registry.shape_for(rows, K, N, groups)
            p = self.plans.lookup(site, shape)
            if p is not None:
                return p
            warnings.warn(
                f"protected site {site!r} shape {shape} is missing from "
                f"the compiled plans (startup census gap); creating a "
                f"lazy registry entry", RuntimeWarning)
        return self.registry.entry(site, rows, K, N, _backend(),
                                   groups=groups)

    def matmul(self, site: str, x: jax.Array, w: Weight) -> jax.Array:
        """Run (or, census-only, record) one protected GEMM site."""
        wq = w[0] if isinstance(w, tuple) else w
        # K comes from the ACTIVATIONS: a packed q8 copy's contraction
        # axis holds ceil(K/4) words, never the true depth
        K, N = x.shape[-1], wq.shape[-1]
        rows = int(np.prod(x.shape[:-1], dtype=np.int64)) if x.ndim > 1 else 1
        if self.census_only:
            self.registry.entry(site, rows, K, N, _backend())
            return jnp.einsum("...k,kn->...n", x.astype(jnp.float32),
                              _unpacked_f32(wq, K, axis=0))
        plan = self._resolve(site, rows, K, N)
        return ProtectedLinear(plan=plan, use_pallas=self.use_pallas)(
            x, w, failed_group=self.failed_group)

    def matmul_fanout(self, sites: tuple, x: jax.Array,
                      ws: tuple) -> list:
        """Run (or record) a FANOUT site group: every site in ``sites``
        multiplies the SAME activations ``x`` against its own weight.

        With ``chain=True`` the group shares one quantize + group-permute
        + pad codec pass — the dominant non-GEMM cost of a protected site
        — and each member then runs its own fused entangle-GEMM-extract
        kernel call. Bit-identical to per-site :meth:`matmul` calls: the
        activation grid depends only on (x, plan, K), which the group
        shares by construction, and extraction is per output column.
        Census-only traces additionally mark the group as chainable
        (:meth:`~repro.ft.registry.PlanRegistry.note_chain`), so the
        compiled plans expose the chain sites at plan-compile time.
        Returns one output per site, in order.
        """
        K = x.shape[-1]
        rows = int(np.prod(x.shape[:-1], dtype=np.int64)) if x.ndim > 1 else 1
        wqs = [w[0] if isinstance(w, tuple) else w for w in ws]
        if self.census_only:
            self.registry.note_chain(tuple(sites))
            return [self.matmul(s, x, w) for s, w in zip(sites, ws)]
        if not self.chain:
            return [self.matmul(s, x, w) for s, w in zip(sites, ws)]

        plans = [self._resolve(s, rows, K, wq.shape[-1])
                 for s, wq in zip(sites, wqs)]
        plan = plans[0].plan
        M = plan.M
        lead = x.shape[:-1]
        xf = x.reshape(rows, K).astype(jnp.float32)
        xq, a_scale = quantize_acts(xf, plan, K)
        pad = (-rows) % M
        if pad:
            xq = jnp.concatenate([xq, jnp.zeros((pad, K), jnp.int32)],
                                 axis=0)
        Rp = rows + pad
        order, inv = group_order(Rp, M)
        xg = xq[order].reshape(M, Rp // M, K)

        from repro.kernels import ops as kops

        outs = []
        for p, w in zip(plans, ws):
            wq_i, w_scale = _split_weight(w)
            N = wq_i.shape[-1]
            rec = kops.entangled_matmul(
                xg, wq_i, p.plan, fuse_epilogue=True,
                failed=self.failed_group, packed=_is_packed(wq_i, K),
                blocks=p.blocks, backend=p.backend)
            y = rec.reshape(Rp, N).astype(jnp.float32)
            y = y[inv][:rows] / (a_scale * w_scale)
            outs.append(y.reshape(*lead, N))
        return outs

    def matmul_grouped(self, site: str, x: jax.Array,
                       w: Weight) -> jax.Array:
        """Run (or record) one grouped per-expert protected GEMM site:
        x [..., E, C, K] against per-expert weights [E, K, N]."""
        wq = w[0] if isinstance(w, tuple) else w
        E, N = wq.shape[-3], wq.shape[-1]
        K = x.shape[-1]
        rows = int(np.prod(x.shape[:-3], dtype=np.int64)) * x.shape[-2]
        if self.census_only:
            self.registry.entry(site, rows, K, N, _backend(), groups=E)
            return jnp.einsum("...eck,ekn->...ecn", x.astype(jnp.float32),
                              _unpacked_f32(wq, K, axis=1))
        plan = self._resolve(site, rows, K, N, groups=E)
        return ProtectedLinear(plan=plan, use_pallas=self.use_pallas)(
            x, w, failed_group=self.failed_group)
