"""ProtectedLinear — the paper's entangled roll-forward wrapped around any
hot-path GEMM.

:func:`protected_matmul` is the one code path every protected projection
runs through: float activations of ANY leading shape are flattened to rows,
quantized onto the plan's eq. (13) integer grid (:mod:`repro.ft.quantize`),
padded with zero rows to a multiple of M (exact — zeros entangle to zeros
and cannot perturb any other stream's accumulator, nor the shared
activation scale), mapped round-robin onto the M entangled streams
(row -> group = row % M, the serving engine's slot -> group contract), and
pushed through the fused Pallas kernel
(:func:`repro.kernels.ops.entangled_matmul`): entangle-on-load, int GEMM,
extraction in the flush epilogue — one pallas_call, zero codec HBM sweeps.
A fail-stopped group's accumulator is statically excluded from the
in-kernel extraction (``failed=r``), so its outputs are rolled forward from
the other M-1 streams and the recovered integers are bit-identical to a
healthy run.

:class:`FTContext` is the object threaded through the model
(``models/api.py -> transformer.apply_stack -> layers``): it decides which
site categories the configured ``ft_scope`` protects, resolves each call
site's :class:`~repro.ft.registry.PlanEntry`, and carries the static
``failed_group`` of the current traced program.  Site names are
``"<category>.<proj>"`` — categories:

  ``head``  the vocab projection (always protected when FT is on)
  ``qkv``   mixer input projections: attention Q/K/V, MLA q/kv_a,
            Mamba in_proj, RG-LRU in_x/in_gate
  ``mlp``   FFN projections: MLP gate/up/down (dense and MoE-shared) and
            the MoE router

``ft_scope`` widens protection cumulatively: ``"head"`` | ``"qkv"`` |
``"mlp"`` (each includes the head) | ``"all"``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.entangle import disentangle as core_disentangle
from repro.core.entangle import entangle as core_entangle
from repro.core.failstop import GARBAGE
from repro.core.plan import EntanglePlan
from repro.ft.quantize import quantize_acts, quantize_weight
from repro.ft.registry import PlanEntry, PlanRegistry, group_rows

# scope -> protected site categories (cumulative; head is always in)
SCOPES: dict[str, frozenset] = {
    "head": frozenset({"head"}),
    "qkv": frozenset({"head", "qkv"}),
    "mlp": frozenset({"head", "mlp"}),
    "all": frozenset({"head", "qkv", "mlp"}),
}

# float weight, or (int8-range int32 weights, scale) pre-quantized at startup
Weight = Union[jax.Array, tuple]


def group_order(R: int, M: int) -> tuple[np.ndarray, np.ndarray]:
    """Static permutation realizing round-robin grouping (row -> group =
    row % M) on top of a contiguous [M, R/M] stream layout.

    ``order[g * R//M + j] = j * M + g`` — position p of the permuted batch
    holds row ``order[p]``; ``inv`` undoes it (``inv[row]`` = position of
    that row's output in the permuted result). Round-robin keeps every
    entangled group populated whenever >= M rows are live, so a fail-stop
    in any group is recoverable from M-1 *other* live groups.
    """
    assert R % M == 0, f"row count {R} must split into M={M} groups"
    order = np.arange(R, dtype=np.int32).reshape(R // M, M).T.reshape(R)
    inv = np.argsort(order).astype(np.int32)
    return order, inv


def protected_matmul(
    x: jax.Array,  # [..., K] float activations
    w: Weight,  # [K, N] float weights, or (wq, w_scale) pre-quantized
    *,
    plan: EntanglePlan,
    failed_group: Optional[int] = None,
    use_pallas: bool = True,
    fuse_epilogue: bool = True,
    blocks=None,
    contiguous: bool = False,
    interpret=None,
) -> jax.Array:
    """Entangled int8 GEMM with in-kernel fail-stop roll-forward.

    Returns dequantized float32 outputs ``[..., N]``. ``contiguous=True``
    keeps the caller's row order as the [M, R/M] group layout (the library
    :func:`repro.serve.ft_logits.ft_logits` contract); the default maps
    rows round-robin onto groups. ``fuse_epilogue=False`` keeps the
    separate disentangle pass for callers that must inject/persist
    entangled outputs; ``use_pallas=False`` is the XLA reference path.
    """
    if isinstance(w, tuple):
        wq, w_scale = w
    else:
        wq, w_scale = quantize_weight(w)
    lead, K = x.shape[:-1], x.shape[-1]
    N = wq.shape[1]
    R = int(np.prod(lead, dtype=np.int64)) if lead else 1
    M = plan.M

    xf = x.reshape(R, K).astype(jnp.float32)
    xq, a_scale = quantize_acts(xf, plan, K)
    pad = (-R) % M
    if pad:
        xq = jnp.concatenate([xq, jnp.zeros((pad, K), jnp.int32)], axis=0)
    Rp = R + pad
    if contiguous:
        inv = None
        xg = xq.reshape(M, Rp // M, K)
    else:
        order, inv = group_order(Rp, M)
        xg = xq[order].reshape(M, Rp // M, K)

    from repro.kernels import ops as kops  # deferred: keeps core import-light

    if use_pallas and fuse_epilogue:
        # production hot path: entangle -> GEMM -> extract in ONE
        # pallas_call; a fail-stopped group is rolled forward in-kernel by
        # statically excluding its accumulator from the extraction (the
        # algebra never reads it, so injecting garbage is equivalent)
        rec = kops.entangled_matmul(
            xg, wq, plan, fuse_epilogue=True, failed=failed_group,
            blocks=blocks, interpret=interpret)
    else:
        if use_pallas:
            delta = kops.entangled_matmul(xg, wq, plan, blocks=blocks,
                                          interpret=interpret)
        else:
            eps = core_entangle(xg, plan)
            delta = jnp.einsum("mbk,kn->mbn", eps, wq).astype(jnp.int32)
        if failed_group is not None:
            delta = delta.at[failed_group].set(GARBAGE)
        rec = core_disentangle(delta, plan, failed=failed_group)

    y = rec.reshape(Rp, N).astype(jnp.float32)
    if inv is not None:
        y = y[inv]
    y = y[:R] / (a_scale * w_scale)
    return y.reshape(*lead, N)


@dataclasses.dataclass(frozen=True)
class ProtectedLinear:
    """One protected GEMM site bound to its registry entry.

    A thin, reusable binding of (site name, plan registry, backend policy):
    calling it resolves the :class:`PlanEntry` for the incoming activation
    shape and runs :func:`protected_matmul` with that entry's plan and
    block sizes. The serving engine holds one per protected projection
    (implicitly, through :class:`FTContext`); library users can construct
    them directly.
    """

    site: str
    registry: PlanRegistry
    use_pallas: bool = True
    interpret: Optional[bool] = None

    def entry(self, x: jax.Array, w: Weight) -> PlanEntry:
        wq = w[0] if isinstance(w, tuple) else w
        K, N = wq.shape
        rows = int(np.prod(x.shape[:-1], dtype=np.int64)) if x.ndim > 1 else 1
        return self.registry.entry(self.site, rows, K, N, _backend())

    def __call__(self, x: jax.Array, w: Weight, *,
                 failed_group: Optional[int] = None,
                 contiguous: bool = False) -> jax.Array:
        e = self.entry(x, w)
        return protected_matmul(
            x, w, plan=e.plan, failed_group=failed_group,
            use_pallas=self.use_pallas, blocks=e.blocks,
            contiguous=contiguous, interpret=self.interpret)


def _backend() -> str:
    """Registry backend tag — mirrors kernels.ops dispatch (compiled on
    TPU, interpret elsewhere)."""
    return jax.default_backend() if jax.default_backend() == "tpu" \
        else "interpret"


@dataclasses.dataclass(frozen=True)
class FTContext:
    """Protection context threaded through the model forward pass.

    Created once by the serving engine at startup and specialized per
    traced program via :meth:`with_failed` (``failed_group`` is a static
    jit argument, so each injected-failure variant is its own compiled
    program sharing the same plans and autotune winners).

    ``census_only=True`` turns :meth:`matmul` into a plain float einsum
    that merely REGISTERS the call shape — the engine's ``warm_autotune``
    abstract-traces the forward pass with such a context to enumerate
    every protected shape without running (or compiling) any kernel.
    """

    registry: PlanRegistry
    scope: str = "head"
    use_pallas: bool = True
    failed_group: Optional[int] = None
    census_only: bool = False

    def __post_init__(self):
        if self.scope not in SCOPES:
            raise ValueError(
                f"unknown ft_scope {self.scope!r}; expected one of "
                f"{sorted(SCOPES)}")

    @property
    def plan(self) -> EntanglePlan:
        return self.registry.plan

    def protects(self, site: str) -> bool:
        return site.split(".", 1)[0] in SCOPES[self.scope]

    def with_failed(self, failed_group: Optional[int]) -> "FTContext":
        return dataclasses.replace(self, failed_group=failed_group)

    def linear(self, site: str) -> ProtectedLinear:
        return ProtectedLinear(site=site, registry=self.registry,
                               use_pallas=self.use_pallas)

    def matmul(self, site: str, x: jax.Array, w: Weight) -> jax.Array:
        """Run (or, census-only, record) one protected GEMM site."""
        lin = self.linear(site)
        lin.entry(x, w)  # register the shape even when census-only
        if self.census_only:
            wq = w[0] if isinstance(w, tuple) else w
            return jnp.einsum("...k,kn->...n", x.astype(jnp.float32),
                              wq.astype(jnp.float32))
        return lin(x, w, failed_group=self.failed_group)
