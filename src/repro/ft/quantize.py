"""Int8 quantization policy of the protected-GEMM subsystem.

One policy, two halves, shared by EVERY protected projection (the serving
head and the in-model QKV/MLP/router sites alike):

  * **weights** — symmetric per-tensor int8: ``scale = 127 / max|w|``,
    values clipped to [-127, 127] and carried in an int32 container (the
    entangled kernel's stream dtype).  This is exactly the policy the head
    GEMM shipped with (``serve/ft_logits.quantize_head`` now re-exports
    :func:`quantize_weight`).
  * **activations** — symmetric per-call integer quantization into the
    plan's eq. (13) budget: a ``K``-deep integer dot of int8 weights
    satisfies ``K * |a|max * 127 <= plan.max_output_magnitude`` iff the
    activation grid is bounded by :func:`activation_budget`.  The budget
    therefore shrinks with the contraction depth — a d_ff-deep MLP down
    projection quantizes coarser than the d_model-deep QKV projections,
    and both stay exactly recoverable.

Quantization trades output precision for protection like any int8 serving
path; the *recovery* is bit-exact — a healthy protected run and a
fail-stop-injected protected run produce identical integers, hence
identical logits and identical tokens (tested).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.plan import EntanglePlan


def quantize_weight(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 weight quantization (int32 container)."""
    amax = jnp.maximum(jnp.max(jnp.abs(w)), 1e-9)
    scale = 127.0 / amax
    return jnp.clip(jnp.round(w * scale), -127, 127).astype(jnp.int32), scale


def activation_budget(plan: EntanglePlan, depth: int) -> int:
    """Largest activation magnitude so a ``depth``-deep int8 dot stays
    within the plan's eq. (13) output range (floor 1 — a degenerate budget
    still round-trips, just coarsely)."""
    return max(plan.max_output_magnitude // (depth * 127), 1)


def quantize_acts(x: jax.Array, plan: EntanglePlan,
                  depth: int) -> tuple[jax.Array, jax.Array]:
    """Quantize float activations ``x`` onto the eq. (13)-budgeted integer
    grid for a ``depth``-deep contraction. Returns (int32 values, scale)."""
    budget = activation_budget(plan, depth)
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-9)
    a_scale = budget / amax
    return jnp.round(x * a_scale).astype(jnp.int32), a_scale
