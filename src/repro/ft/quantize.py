"""Int8 quantization policy of the protected-GEMM subsystem.

One policy, two halves, shared by EVERY protected projection (the serving
head and the in-model QKV/MLP/router sites alike):

  * **weights** — symmetric per-tensor int8: ``scale = 127 / max|w|``,
    values clipped to [-127, 127] and carried in an int32 container (the
    entangled kernel's stream dtype).  This is exactly the policy the head
    GEMM shipped with (``repro.ft.heads.quantize_head`` re-exports
    :func:`quantize_weight`), applied per layer / per expert by the
    startup hoist via :func:`quantize_weight_stacked`.
  * **activations** — symmetric PER-ROW integer quantization into the
    plan's eq. (13) budget: a ``K``-deep integer dot of int8 weights
    satisfies ``K * |a|max * 127 <= plan.max_output_magnitude`` iff the
    activation grid is bounded by :func:`activation_budget`.  The budget
    therefore shrinks with the contraction depth — a d_ff-deep MLP down
    projection quantizes coarser than the d_model-deep QKV projections,
    and both stay exactly recoverable.  The scale is per ROW (one grid per
    sample), not per tensor: a request's integer stream — and therefore
    its tokens — is a function of its own activations only, never of
    whichever other requests happen to be co-resident in the batch.  This
    is what makes serving-side scheduling (continuous batching, mid-flight
    slot refill, chunked admission) token-transparent: admitting, evicting
    or refilling neighbours cannot move any other request's quantization
    grid, so the entangled roll-forward stays bit-identical no matter WHEN
    a slot was filled.

Quantization trades output precision for protection like any int8 serving
path; the *recovery* is bit-exact — a healthy protected run and a
fail-stop-injected protected run produce identical integers, hence
identical logits and identical tokens (tested).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.plan import EntanglePlan
from repro.kernels.codec import pack_int8


# observability: how often the eq.-13 weight policy actually runs. The v2
# plan-compile flow quantizes every protected site's weights ONCE at engine
# startup (repro.ft.plans.prepare_params), so a traced decode/prefill step
# must never bump this counter — tests assert exactly that (the hoisted-
# quantization contract). Plain dict so tests can reset it in place.
TRACE_STATS = {"weight_quantize_calls": 0}


def quantize_weight(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 weight quantization (int32 container)."""
    TRACE_STATS["weight_quantize_calls"] += 1
    amax = jnp.maximum(jnp.max(jnp.abs(w)), 1e-9)
    scale = 127.0 / amax
    return jnp.clip(jnp.round(w * scale), -127, 127).astype(jnp.int32), scale


def quantize_weight_stacked(w: jax.Array, *, packed: bool = False) -> dict:
    """Per-matrix int8 quantization of a stacked weight ``[..., K, N]``.

    Every leading axis (layer-repeat, expert) gets its own scale: the
    quantization is vmapped over all but the last two dims, so a scanned
    stack of layers (or a stack of MoE experts) quantizes each matrix on
    its own grid — exactly what the per-call policy produced, now computed
    once at startup. Returns ``{"w": int32 [..., K, N], "scale": [...]}``,
    the ``q8`` pytree entry :func:`repro.ft.plans.prepare_params` installs
    next to the float master.

    ``packed=True`` additionally packs the int8 values 4-per-int32-word
    along the contraction axis (:func:`repro.kernels.codec.pack_int8`), so
    the stored copy is ``[..., ceil(K/4), N]`` — its true int8 bytes in
    HBM. The kernels unpack on load; consumers detect packedness from the
    contraction-axis length (``w.shape[-2] != K``).
    """
    fn = quantize_weight
    for _ in range(w.ndim - 2):
        fn = jax.vmap(fn)
    wq, scale = fn(w)
    if packed:
        wq = pack_int8(wq, axis=-2)
    return {"w": wq, "scale": scale}


def activation_budget(plan: EntanglePlan, depth: int) -> int:
    """Largest activation magnitude so a ``depth``-deep int8 dot stays
    within the plan's eq. (13) output range (floor 1 — a degenerate budget
    still round-trips, just coarsely)."""
    return max(plan.max_output_magnitude // (depth * 127), 1)


def chain_budget(plan: EntanglePlan, depths: Sequence[int]) -> int:
    """Activation budget for an entangled-domain GEMM *chain*.

    A chain of GEMMs with contraction depths ``K_1 .. K_n`` (each against
    int8 weights) amplifies the first hop's activations by at most
    ``prod(K_i * 127)`` before the single final extraction, so the first
    hop's integer grid must satisfy
    ``budget * prod(K_i * 127) <= plan.max_output_magnitude`` for the whole
    chain to stay within the plan's eq. (13) range at every hop. Returns 0
    when no such grid exists — the chain is infeasible under this plan and
    the executor must fall back to per-GEMM extraction (which it does; see
    :func:`repro.ft.protected.entangled_chain`).
    """
    amp = 1
    for K in depths:
        amp *= int(K) * 127
    return plan.max_output_magnitude // amp


def quantize_acts(x: jax.Array, plan: EntanglePlan, depth: int, *,
                  budget: int = None) -> tuple[jax.Array, jax.Array]:
    """Quantize float activations ``x`` onto the eq. (13)-budgeted integer
    grid for a ``depth``-deep contraction. Returns (int32 values, scale),
    where the scale is PER ROW — shaped like ``x`` with the contraction
    axis reduced to 1, so it broadcasts against the row's outputs.

    Per-row scales keep every sample's integer stream a function of its
    own values: batch composition (which slots are live, what garbage an
    inactive row holds, when admission refilled a slot) can never move
    another row's grid. Each row's entries are bounded by ``budget``, so
    the eq. (13) output bound holds row-wise exactly as it did for the
    old shared per-tensor grid. ``budget`` overrides the single-GEMM
    budget (the chain executor passes :func:`chain_budget`'s tighter
    grid)."""
    if budget is None:
        budget = activation_budget(plan, depth)
    amax = jnp.maximum(jnp.max(jnp.abs(x), axis=-1, keepdims=True), 1e-9)
    a_scale = budget / amax
    return jnp.round(x * a_scale).astype(jnp.int32), a_scale
