"""Plan registry: one entry per (site, shape, M, backend) protected GEMM.

The serving engine constructs ONE registry at startup; every protected
projection — head, QKV, MLP up/down, MoE router — resolves its
:class:`PlanEntry` here at trace time, so the whole forward pass shares a
single :class:`~repro.core.plan.EntanglePlan` (stable autotune/compile keys
across the serving lifetime) while each call shape gets its own block-size
decision:

  * ``blocks`` policy ``None`` — shape-clamped power-of-two defaults
    (:func:`default_blocks`): the per-group row count of a decode step is
    tiny (max_batch / M), so the wrapper's MXU-aligned 128-row default
    would pad it ~64x with zero rows every step;
  * ``blocks`` policy ``"auto"`` — the :mod:`repro.kernels.autotune`
    subsystem; the engine's ``warm_autotune`` pre-sweeps every registered
    shape eagerly so the in-jit resolution is a pure cache hit.

Entries are created lazily at trace time (a Python dict lookup during
tracing — never inside the compiled program) and double as the protected
shape census ``warm_autotune`` iterates.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.plan import EntanglePlan


def group_rows(rows: int, M: int) -> int:
    """Per-group row count after padding ``rows`` to a multiple of M —
    the single source of the kernel-call batch dim, shared by the
    protected matmul, the registry keys and the autotune warmup."""
    return -(-rows // M)


def _pow2_cover(n: int, lo: int, hi: int) -> int:
    """Smallest power of two >= min(n, hi), floored at ``lo``."""
    p = lo
    while p < min(max(n, 1), hi):
        p *= 2
    return p


def default_blocks(Bg: int, K: int, N: int) -> dict:
    """Shape-clamped block sizes for one (Bg, K, N) protected GEMM."""
    return {"bb": _pow2_cover(Bg, 8, 128),
            "bn": _pow2_cover(N, 32, 256),
            "bk": _pow2_cover(K, 32, 256)}


@dataclasses.dataclass(frozen=True)
class PlanEntry:
    """Resolved protection parameters of one GEMM site at one call shape."""

    site: str
    shape: tuple  # (M, Bg, K, N) — the entangled kernel call signature
    backend: str
    plan: EntanglePlan
    blocks: object  # None | dict | "auto" — passed through to kernels.ops


class PlanRegistry:
    """(site, shape, M, backend) -> :class:`PlanEntry` map."""

    def __init__(self, plan: EntanglePlan, *, blocks: object = None):
        self.plan = plan
        self.blocks_policy = blocks
        self._entries: dict[tuple, PlanEntry] = {}

    @staticmethod
    def key(site: str, shape: tuple, M: int, backend: str) -> tuple:
        return (site, shape, M, backend)

    def entry(self, site: str, rows: int, K: int, N: int,
              backend: str) -> PlanEntry:
        """Resolve (creating on first use) the entry for one call site."""
        shape = (self.plan.M, group_rows(rows, self.plan.M), K, N)
        k = self.key(site, shape, self.plan.M, backend)
        e = self._entries.get(k)
        if e is None:
            blocks = self.blocks_policy
            if blocks is None:
                blocks = default_blocks(*shape[1:])
            e = PlanEntry(site=site, shape=shape, backend=backend,
                          plan=self.plan, blocks=blocks)
            self._entries[k] = e
        return e

    def entries(self) -> list[PlanEntry]:
        return list(self._entries.values())

    def census(self) -> dict:
        """{(site, (M, Bg, K, N)): blocks} — what warm_autotune iterates."""
        return {(e.site, e.shape): e.blocks for e in self._entries.values()}

    def get(self, site: str, shape: tuple,
            backend: str) -> Optional[PlanEntry]:
        return self._entries.get(self.key(site, shape, self.plan.M, backend))
