"""Plan registry: one :class:`ProtectionPlan` per (site, shape, M, backend)
protected GEMM.

The serving engine constructs ONE registry at startup; every protected
projection — head, QKV, MLP up/down, MoE router, the attention/SSM output
projections and the MoE per-expert GEMMs — resolves its
:class:`ProtectionPlan` here, so the whole forward pass shares a single
:class:`~repro.core.plan.EntanglePlan` (stable autotune/compile keys across
the serving lifetime) while each call shape gets its own block-size
decision:

  * ``blocks`` policy ``None`` — shape-clamped power-of-two defaults
    (:func:`default_blocks`): the per-group row count of a decode step is
    tiny (max_batch / M), so the wrapper's MXU-aligned 128-row default
    would pad it ~64x with zero rows every step;
  * ``blocks`` policy ``"auto"`` — the :mod:`repro.kernels.autotune`
    subsystem; the engine's ``warm_autotune`` pre-sweeps every registered
    shape eagerly so the in-jit resolution is a pure cache hit.

In the v2 flow the registry is populated ONCE at startup by the engine's
census-only abstract traces and then frozen into an immutable
:class:`repro.ft.plans.CompiledPlans` via :func:`repro.ft.plans.
compile_plans`; lazy trace-time creation remains for library users calling
:class:`~repro.ft.protected.FTContext` without a compile step.

Plan shapes: a plain GEMM site's shape is ``(M, Bg, K, N)``; a grouped
(MoE per-expert) site's shape is ``(M, E, Bg, K, N)`` with
``grouped=True`` — ``Bg`` then counts per-expert rows per stream.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.plan import EntanglePlan


def group_rows(rows: int, M: int) -> int:
    """Per-group row count after padding ``rows`` to a multiple of M —
    the single source of the kernel-call batch dim, shared by the
    protected matmul, the registry keys and the autotune warmup."""
    return -(-rows // M)


def _pow2_cover(n: int, lo: int, hi: int) -> int:
    """Smallest power of two >= min(n, hi), floored at ``lo``."""
    p = lo
    while p < min(max(n, 1), hi):
        p *= 2
    return p


def default_blocks(Bg: int, K: int, N: int) -> dict:
    """Shape-clamped block sizes for one (Bg, K, N) protected GEMM."""
    return {"bb": _pow2_cover(Bg, 8, 128),
            "bn": _pow2_cover(N, 32, 256),
            "bk": _pow2_cover(K, 32, 256)}


@dataclasses.dataclass(frozen=True)
class ProtectionPlan:
    """Immutable protection parameters of one GEMM site at one call shape.

    Built ahead of time (engine startup census -> ``compile_plans``) or
    lazily at trace time (library use); either way every field is static:
    a :class:`~repro.ft.protected.ProtectedLinear` bound to a plan is a
    pure executor, and the traced program can never re-derive blocks,
    shapes or entanglement parameters mid-flight.
    """

    site: str
    shape: tuple  # (M, Bg, K, N) — or (M, E, Bg, K, N) when grouped
    backend: str
    plan: EntanglePlan
    blocks: object  # None | dict | "auto" — passed through to kernels.ops
    grouped: bool = False
    # the site's startup-quantized q8 copy is int8-packed 4-per-word along
    # K (kernels unpack on load); drives the autotune warm keys and the
    # prepare_params packing policy — the executor itself re-derives
    # packedness from the weight's contraction-axis length
    packed: bool = False


# pre-v2 name: registry entries used to be mutable-registry-only objects
PlanEntry = ProtectionPlan


class PlanRegistry:
    """(site, shape, M, backend) -> :class:`ProtectionPlan` map."""

    def __init__(self, plan: EntanglePlan, *, blocks: object = None,
                 packed: bool = False):
        self.plan = plan
        self.blocks_policy = blocks
        self.packed = packed
        self._entries: dict[tuple, ProtectionPlan] = {}
        # chainable site groups noted by the census-only traces: tuples of
        # sites that consume the SAME activations and are strictly linear,
        # so one entangle/quantize pass feeds all of them and the chain
        # executor keeps them in the entangled domain
        self._chains: set[tuple] = set()

    @staticmethod
    def key(site: str, shape: tuple, M: int, backend: str) -> tuple:
        return (site, shape, M, backend)

    def shape_for(self, rows: int, K: int, N: int,
                  groups: Optional[int] = None) -> tuple:
        """The kernel-call shape key of a site invocation: ``rows`` is the
        flattened sample count (per expert when ``groups`` is given)."""
        Bg = group_rows(rows, self.plan.M)
        if groups is None:
            return (self.plan.M, Bg, K, N)
        return (self.plan.M, groups, Bg, K, N)

    def entry(self, site: str, rows: int, K: int, N: int,
              backend: str, *, groups: Optional[int] = None) -> ProtectionPlan:
        """Resolve (creating on first use) the plan for one call site."""
        shape = self.shape_for(rows, K, N, groups)
        k = self.key(site, shape, self.plan.M, backend)
        e = self._entries.get(k)
        if e is None:
            blocks = self.blocks_policy
            if blocks is None:
                blocks = default_blocks(*shape[-3:])
            e = ProtectionPlan(site=site, shape=shape, backend=backend,
                               plan=self.plan, blocks=blocks,
                               grouped=groups is not None,
                               packed=self.packed)
            self._entries[k] = e
        return e

    def note_chain(self, sites: tuple) -> None:
        """Record one chainable site group (census-only traces call this
        when a fanout/chain executor covers ``sites`` with one codec
        pass)."""
        if len(sites) >= 2:
            self._chains.add(tuple(sites))

    def chains(self) -> frozenset:
        """Chainable site groups noted during the census traces."""
        return frozenset(self._chains)

    def entries(self) -> list[ProtectionPlan]:
        return list(self._entries.values())

    def census(self) -> dict:
        """{(site, shape): blocks} — what warm_autotune iterates; grouped
        sites carry 5-tuple shapes."""
        return {(e.site, e.shape): e.blocks for e in self._entries.values()}

    def get(self, site: str, shape: tuple,
            backend: str) -> Optional[ProtectionPlan]:
        return self._entries.get(self.key(site, shape, self.plan.M, backend))
