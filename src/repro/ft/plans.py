"""Ahead-of-time protection planning — the v2 top of the subsystem.

PR 4 protected GEMMs *at call time*: every traced projection re-resolved
its registry entry and re-quantized its float weights onto the eq. (13)
int8 grid inside the traced graph — per call, per failed-group retrace.
This module moves both to startup:

  :func:`compile_plans`   walks the protected-site census (the registry
                          populated by the engine's census-only abstract
                          traces) and freezes it into an immutable
                          :class:`CompiledPlans` — one
                          :class:`~repro.ft.registry.ProtectionPlan` per
                          (site, call shape), block sizes bound, backend
                          namespaced. The FTContext threaded through the
                          model then only *looks up* plans; a traced step
                          can never create or mutate one.
  :func:`prepare_params`  quantizes every protected site's weights ONCE
                          (per layer / per expert, via
                          :func:`~repro.ft.quantize.quantize_weight_stacked`)
                          and installs the integer copies INSIDE the params
                          pytree — a ``q8`` entry next to each dense site's
                          float master, a ``<name>_q8`` sibling for raw
                          MoE/router arrays. ``lax.scan`` over layer
                          repeats slices the quantized stack exactly like
                          the float one, so each layer keeps its own grid
                          while the traced decode/prefill graph contains
                          ZERO weight-quantization ops (asserted by the
                          ``repro.ft.quantize.TRACE_STATS`` trace-count
                          tests). Float masters stay in place for the
                          unprotected/training paths; the integer copies
                          cost one extra weight-sized buffer per protected
                          site (int8 values in the kernel's int32
                          container — packing is a recorded follow-up).

Site discovery is declarative: :data:`PROTECTED_WEIGHT_KEYS` maps the
param-dict key of every protectable projection to its scope category, so
``prepare_params`` needs no model-specific walker — adding a protected
site to a model means giving its weight dict one of these keys (or adding
a new key here) plus the ``site=`` kwarg at the ``dense()`` call.
"""
from __future__ import annotations

from typing import Iterable, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.ft.quantize import quantize_weight_stacked
from repro.ft.registry import PlanRegistry, ProtectionPlan

# param-tree key -> scope category, for every protectable projection.
# Dense sites are dicts holding a float "w"; raw sites (MoE expert stacks,
# the router) are bare arrays and get a "<key>_q8" sibling instead.
PROTECTED_WEIGHT_KEYS: dict[str, str] = {
    # mixer input projections (category "qkv")
    "wq": "qkv", "wk": "qkv", "wv": "qkv",          # GQA/MQA attention
    "wq_a": "qkv", "wq_b": "qkv", "wkv_a": "qkv",   # MLA low-rank q / kv
    "in_proj": "qkv",                               # Mamba
    "in_x": "qkv", "in_gate": "qkv",                # RG-LRU
    # FFN projections (category "mlp"; includes the MoE shared expert)
    "gate": "mlp", "up": "mlp", "down": "mlp",
    "router": "mlp",                                # raw [D, E] array
    # output projections (category "out")
    "wo": "out",                                    # attention / MLA
    "out_proj": "out",                              # Mamba
    "out": "out",                                   # RG-LRU
    # MoE per-expert GEMMs (category "moe"; raw [E, D, F] stacks)
    "we_gate": "moe", "we_up": "moe", "we_down": "moe",
}

# subtrees never touched by the serving forward pass — skipped so their
# weights are not needlessly duplicated (MTP is a train-only head)
_SKIP_SUBTREES = frozenset({"mtp"})


def _is_float_weight(v) -> bool:
    return (hasattr(v, "ndim") and hasattr(v, "dtype") and v.ndim >= 2
            and jnp.issubdtype(v.dtype, jnp.floating))


def prepare_params(params, *, scope: str, packed: bool = True):
    """Return a copy of ``params`` with every in-scope protected site's
    weights pre-quantized (see module docstring). Structure-preserving:
    float masters and all other leaves pass through untouched, so the
    result drops into every existing model entry point.

    ``packed=True`` (the default) stores each q8 copy int8-packed
    4-per-int32-word along the contraction axis — 1x its true bytes in
    HBM instead of the 4x int32 container; the kernels unpack on load
    (``packed=False`` keeps the legacy int32-container copies, e.g. for
    the unpacked benchmark baseline).
    """
    from repro.ft.protected import SCOPES  # deferred: protected imports us

    cats = SCOPES[scope]

    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                cat = PROTECTED_WEIGHT_KEYS.get(k)
                if k in _SKIP_SUBTREES or cat not in cats:
                    out[k] = walk(v) if k not in _SKIP_SUBTREES else v
                elif isinstance(v, dict) and _is_float_weight(v.get("w")):
                    nv = dict(v)
                    nv["q8"] = quantize_weight_stacked(v["w"], packed=packed)
                    out[k] = nv
                elif _is_float_weight(v):
                    out[k] = v
                    out[k + "_q8"] = quantize_weight_stacked(v, packed=packed)
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(x) for x in node)
        return node

    return walk(params)


class CompiledPlans:
    """Immutable (site, shape) -> :class:`ProtectionPlan` map.

    Built once at startup by :func:`compile_plans`; the serving FTContext
    resolves every protected projection here at trace time. Lookup misses
    return ``None`` (the context falls back to a lazily created registry
    entry with a warning — a census gap must degrade, not crash, a
    serving process)."""

    def __init__(self, plans: Iterable[ProtectionPlan],
                 chains: Iterable[tuple] = ()):
        self._plans: dict[tuple, ProtectionPlan] = {
            (p.site, p.shape): p for p in plans}
        # chainable site groups marked by the engine census at plan-compile
        # time: each tuple names sites that share their input activations
        # and run strictly linearly, so the fanout/chain executor covers
        # them with ONE quantize+entangle pass (see ft/protected.py)
        self._chains: frozenset = frozenset(tuple(c) for c in chains)
        # observability: how many lookups fell through to the lazy-entry
        # fallback. Steady-state serving must keep this at 0 — mid-flight
        # slot refill reuses the census'd [Bp, bucket] chunk shapes, so a
        # refill can never request a shape the startup census missed
        # (tested; see ServeEngine and tests/test_serve_refill.py).
        self.misses = 0

    def lookup(self, site: str, shape: tuple) -> Optional[ProtectionPlan]:
        plan = self._plans.get((site, shape))
        if plan is None:
            self.misses += 1
        return plan

    def assert_covers(self, census: Mapping):
        """Raise if any censused (site, shape) lacks a compiled plan — the
        engine calls this right after :func:`compile_plans` so a census /
        compile drift fails loudly at startup instead of degrading to lazy
        per-trace entries mid-serve."""
        missing = [k for k in census if k not in self._plans]
        if missing:
            raise AssertionError(
                f"compiled plans miss {len(missing)} censused sites: "
                f"{sorted(missing)[:4]}...")

    @property
    def chains(self) -> frozenset:
        """Chainable site groups discovered by the compile-time census."""
        return self._chains

    def plans(self) -> tuple:
        return tuple(self._plans.values())

    def sites(self) -> frozenset:
        return frozenset(p.site for p in self._plans.values())

    def categories(self) -> frozenset:
        """Protected scope categories covered by the compiled plans."""
        return frozenset(p.site.split(".", 1)[0]
                         for p in self._plans.values())

    def __len__(self) -> int:
        return len(self._plans)

    def __iter__(self):
        return iter(self._plans.values())

    def __repr__(self) -> str:
        return (f"CompiledPlans({len(self)} plans, "
                f"sites={sorted(self.sites())})")


def compile_plans(registry: PlanRegistry,
                  census: Optional[Mapping] = None) -> CompiledPlans:
    """Freeze the registry's protected-site census into immutable per-site
    plans.

    ``census`` (``{(site, shape): blocks}``, the engine's
    ``protected_census``) selects which entries to freeze; ``None`` takes
    every entry the registry holds. The registry must already be populated
    — in the engine that happens via the census-only abstract traces of
    the decode step and every prefill chunk width, so the compiled set
    covers every shape a traced program can request.
    """
    entries = registry.entries()
    if census is not None:
        wanted = set(census)
        entries = [e for e in entries if (e.site, e.shape) in wanted]
    return CompiledPlans(entries, chains=registry.chains())
