"""Entangled int8 logits projection — the head-GEMM entries of the
protected subsystem (formerly ``repro.serve.ft_logits``; that shim is
REMOVED — this module is the only surface, with :mod:`repro.serve`
re-exporting the names for convenience).

The head GEMM (hidden [B, D] x head [D, V]) is sesquilinear, so it runs
directly on entangled inputs through :func:`repro.ft.protected_matmul`:
the batch is split into M request groups (streams), activations are
fixed-point-quantized within the plan's eq. (13) budget, and the fused
kernel rolls any single group's fail-stop forward from the other M-1
entangled accumulators inside the same kernel call.

:func:`ft_logits` is the library form (caller-chosen contiguous grouping).
:func:`ft_logits_decode` is the batched serving engine's per-step entry:
slots map round-robin to groups (slot -> group = slot % M) so every group
stays populated under continuous batching, and the
:class:`~repro.core.plan.EntanglePlan` is made once at engine startup and
reused every step. :func:`ft_logits_prefill` is the admission-time entry —
the first token of every bucketed batched prefill goes through the same
fused kernel (and the same startup plan), so a fail-stop during prefill
rolls forward exactly like one during decode.

Returns dequantized float logits. Integer recovery is EXACT (tests assert
bit-equality under injected failure); the quantization itself trades logits
precision for protection like any int8 serving path. The head weights are
quantized ONCE at engine startup (:func:`quantize_head` — the subsystem's
weight policy), never inside a traced step.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.core.plan import EntanglePlan, make_plan
from repro.ft.protected import group_order, protected_matmul
from repro.ft.quantize import quantize_weight as quantize_head  # noqa: F401
# re-exported compat name: quantize_head is the subsystem's weight policy


def ft_logits(
    h: jax.Array,  # [B, D] float hidden states (final norm applied)
    head_q: jax.Array,  # [D, V] int8-range int32 weights
    w_scale: jax.Array,
    *,
    M: int = 4,
    plan: Optional[EntanglePlan] = None,
    failed_group: Optional[int] = None,
    use_pallas: bool = True,
    fuse_epilogue: bool = True,
    blocks=None,
) -> jax.Array:
    """Library form: rows grouped contiguously ([M, B/M] caller layout)."""
    B = h.shape[0]
    assert B % M == 0, f"batch {B} must split into M={M} request groups"
    plan = plan or make_plan(M, 32)
    return protected_matmul(
        h, (head_q, w_scale), plan=plan, failed_group=failed_group,
        use_pallas=use_pallas, fuse_epilogue=fuse_epilogue, blocks=blocks,
        contiguous=True)


def decode_group_order(B: int, M: int):
    """Compat alias for :func:`repro.ft.protected.group_order` — the
    engine's slot -> group = slot % M permutation."""
    return group_order(B, M)


def ft_logits_decode(
    h: jax.Array,  # [B, D] hidden states of ONE engine decode step
    head_q: jax.Array,  # [D, V] int8-range int32 weights
    w_scale: jax.Array,
    *,
    plan: EntanglePlan,
    failed_group: Optional[int] = None,
    use_pallas: bool = True,
    fuse_epilogue: bool = True,
    blocks=None,
) -> jax.Array:
    """The serving engine's per-step entry: one fused entangled head GEMM
    over the whole slot batch, slots mapped round-robin to groups
    (slot -> group = slot % plan.M).

    Unlike :func:`ft_logits` the plan is REQUIRED: the engine makes it once
    at startup and reuses it every step, so no per-step (l, k) re-planning
    and a stable autotune/compile key across the serving lifetime.
    """
    return protected_matmul(
        h, (head_q, w_scale), plan=plan, failed_group=failed_group,
        use_pallas=use_pallas, fuse_epilogue=fuse_epilogue, blocks=blocks)


def ft_logits_prefill(
    h: jax.Array,  # [n, D] per-request last-prompt hidden states
    head_q: jax.Array,  # [D, V] int8-range int32 weights
    w_scale: jax.Array,
    *,
    plan: EntanglePlan,
    failed_group: Optional[int] = None,
    use_pallas: bool = True,
    fuse_epilogue: bool = True,
    blocks=None,
) -> jax.Array:
    """Admission-time entry: project the last-prompt hidden states gathered
    from a bucketed batched prefill through the SAME fused entangled kernel
    (and the same startup :class:`~repro.core.plan.EntanglePlan`) as decode.

    Rows map round-robin to groups like decode (row -> group = row % M);
    an admission batch that does not divide into M groups is padded with
    zero rows inside :func:`repro.ft.protected_matmul` (exact: zeros
    entangle to zeros and cannot perturb any other stream's accumulator).
    Activation quantization is PER ROW (:func:`repro.ft.quantize_acts`),
    so garbage rows (empty admission slots) cannot move a live row's grid —
    the caller still zeroes them, like the decode path's ``active``
    masking, so their garbage logits are deterministic zeros.
    """
    return protected_matmul(
        h, (head_q, w_scale), plan=plan, failed_group=failed_group,
        use_pallas=use_pallas, fuse_epilogue=fuse_epilogue, blocks=blocks)
