"""Float <-> fixed-point bridge for applying entanglement to float pipelines.

The paper's scheme is exact only on integers. The framework applies it to
float data (gradients, activations) by quantizing to fixed point first:

  * per-tensor symmetric scaling into the entanglement plan's output budget,
  * optional stochastic rounding (unbiased — required for gradient
    compression to leave SGD/Adam expectations unchanged),
  * reduction headroom: a sum over ``depth`` terms (cross-replica gradient
    reduce-scatter, dot-product accumulation) multiplies magnitudes by up to
    ``depth``; the budget is pre-divided so the *summed* stream still
    satisfies the eq. (13) range contract.

This is also the framework's gradient-compression codec (int16 wire format),
independent of fault tolerance.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def fit_scale(x: Array, max_magnitude: int, reduction_depth: int = 1) -> Array:
    """Largest power-of-two scale s.t. |x|*scale stays in budget after an
    exact ``reduction_depth``-term sum. Power-of-two keeps dequantization a
    pure exponent adjustment (no rounding in scale itself)."""
    budget = jnp.float32(max_magnitude // max(reduction_depth, 1))
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    amax = jnp.maximum(amax, jnp.finfo(jnp.float32).tiny)
    exp = jnp.floor(jnp.log2(budget / amax))
    return jnp.exp2(exp)


def quantize(
    x: Array,
    max_magnitude: int,
    reduction_depth: int = 1,
    stochastic_key: Optional[jax.Array] = None,
) -> tuple[Array, Array]:
    """Quantize floats to int32 within the entanglement budget.

    Returns (q, scale) with dequantization x ~= q / scale.
    """
    scale = fit_scale(x, max_magnitude, reduction_depth)
    y = x.astype(jnp.float32) * scale
    if stochastic_key is not None:
        noise = jax.random.uniform(stochastic_key, y.shape, jnp.float32) - 0.5
        q = jnp.floor(y + 0.5 + noise)
    else:
        q = jnp.round(y)
    return q.astype(jnp.int32), scale


def dequantize(q: Array, scale: Array, dtype=jnp.float32) -> Array:
    return q.astype(jnp.float32) / scale if dtype == jnp.float32 else (
        q.astype(jnp.float32) / scale
    ).astype(dtype)
