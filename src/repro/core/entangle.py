"""Numerical entanglement — the paper's core contribution (Sec. III).

Entanglement (eq. 6 / 14 / 15): each of ``M >= 3`` integer streams is
overwritten in place by the superposition of itself and its cyclic
predecessor left-shifted by ``l`` bits::

    eps_m = S_l{ c_{(m-1) mod M} } + c_m            (circulant operator E)

Any linear / sesquilinear / bijective (LSB) op applied per-stream commutes
with E, so entangled outputs satisfy ``delta_m = S_l{d_{m-1}} + d_m``.

Disentanglement (eq. 16-19) recovers ALL ``M`` outputs from any ``M-1``
entangled outputs using only adds and arithmetic shifts. With the failed
stream index ``r``, the telescoping temporary

    d_temp = sum_{m=0}^{M-2} (-1)^m S_{(M-2-m)l}{ delta_{(r+1+m) mod M} }
           = 2^{(M-1)l} * d_r  +  (-1)^M * d_{(r+M-1) mod M}

is evaluated in Horner form (T_1 = delta_{r+1}; T_j = S_l{T_{j-1}} +
(-1)^{j-1} delta_{(r+j) mod M}), needing up to ``2w`` bits — carried natively in int32
when it fits, else as a :mod:`repro.core.wideint` dual word (paper Remark 1).
``d_r`` and ``d_{(r+M-1)}`` split out of ``d_temp`` by sign-extension and
exact shifts; the remaining streams follow the chain of eq. (19).

All arithmetic is two's-complement ring arithmetic mod ``2**w``: wrap-around
in intermediates is harmless because the final values are bounded by the
eq. (13) range contract ``|d| <= max_output_magnitude``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import wideint
from repro.core.plan import EntanglePlan

__all__ = [
    "entangle",
    "disentangle",
    "extract",
    "entangle_kernel_addsub",
    "reentangle_stream",
]


def _check_streams(x: jax.Array, plan: EntanglePlan, axis: int) -> None:
    if x.shape[axis] != plan.M:
        raise ValueError(
            f"stream axis {axis} has size {x.shape[axis]}, expected M={plan.M}"
        )
    if not jnp.issubdtype(x.dtype, jnp.integer):
        raise TypeError(f"entanglement operates on integer streams, got {x.dtype}")


def entangle(c: jax.Array, plan: EntanglePlan, axis: int = 0) -> jax.Array:
    """Apply the circulant entanglement operator E (eq. 14/15).

    Args:
      c: integer array with the M streams stacked along ``axis``.
      plan: entanglement parameters (M, w, l, k).
      axis: stream axis.

    Returns:
      Entangled array of identical shape/dtype (written "in place" in the
      paper's sense: same storage footprint, no extra streams).
    """
    _check_streams(c, plan, axis)
    c = c.astype(jnp.int32) if c.dtype != jnp.int32 else c
    prev = jnp.roll(c, 1, axis=axis)  # position m holds c_{(m-1) mod M}
    return jnp.left_shift(prev, plan.l) + c


def entangle_kernel_addsub(g: jax.Array, plan: EntanglePlan) -> jax.Array:
    """Self-entangle the kernel for op in {+, -} (paper footnote 3)."""
    g = g.astype(jnp.int32)
    return jnp.left_shift(g, plan.l) + g


def _horner_dtemp_i32(deltas: list[jax.Array], l: int) -> jax.Array:
    """d_temp in a single int32 word (valid when plan.temp_bits <= 32)."""
    t = deltas[0]
    for j, d in enumerate(deltas[1:], start=2):
        t = jnp.left_shift(t, l)
        t = (t - d) if (j % 2 == 0) else (t + d)  # sign (-1)^(j-1)
    return t


def disentangle(
    delta: jax.Array,
    plan: EntanglePlan,
    failed: Optional[int] = None,
    axis: int = 0,
) -> jax.Array:
    """Recover all M true outputs from entangled outputs (eq. 16-19).

    Args:
      delta: entangled LSB outputs, M streams stacked along ``axis``.
      plan: entanglement parameters.
      failed: index of the fail-stopped stream whose data must NOT be read
        (its slice may hold garbage). ``None`` means no failure; stream 0's
        data is then simply not consulted (the algebra never needs all M).
      axis: stream axis.

    Returns:
      int32 array of the M disentangled outputs, original stream order.
    """
    _check_streams(delta, plan, axis)
    if axis != 0:
        delta = jnp.moveaxis(delta, axis, 0)
    delta = delta.astype(jnp.int32)

    M, l = plan.M, plan.l
    r = 0 if failed is None else int(failed) % M
    B = (M - 1) * l  # d_r lives above bit B in d_temp
    sign = -1 if (M % 2) else 1  # (-1)^M
    q = (r + M - 1) % M

    deltas = [delta[(r + 1 + m) % M] for m in range(M - 1)]

    if plan.temp == "dualword":
        t = wideint.widen(deltas[0])
        for j, d in enumerate(deltas[1:], start=2):
            t = wideint.shl(t, l)
            t = (
                wideint.sub(t, wideint.widen(d))
                if (j % 2 == 0)
                else wideint.add(t, wideint.widen(d))
            )
        t_lo = wideint.extract_low_signed(t, B)  # == (-1)^M * d_q
        d_q = (sign * t_lo).astype(jnp.int32)
        d_r = wideint.shr_exact_to_i32(wideint.sub(t, wideint.widen(t_lo)), B)
    else:  # 'int32' (and the int64np oracle lives in kernels/ref.py)
        t = _horner_dtemp_i32(deltas, l)
        shift = 32 - B
        t_lo = jnp.right_shift(jnp.left_shift(t, shift), shift)
        d_q = (sign * t_lo).astype(jnp.int32)
        d_r = jnp.right_shift(t - t_lo, B)

    out: list[Optional[jax.Array]] = [None] * M
    out[r], out[q] = d_r, d_q
    for m in range(1, M - 1):  # eq. (19) chain
        idx = (r + m) % M
        prev = out[(r + m - 1) % M]
        out[idx] = delta[idx] - jnp.left_shift(prev, l)

    res = jnp.stack(out, axis=0)
    if axis != 0:
        res = jnp.moveaxis(res, 0, axis)
    return res


def extract(delta: jax.Array, plan: EntanglePlan, axis: int = 0) -> jax.Array:
    """Failure-free extraction of results (same mechanism, r := 0)."""
    return disentangle(delta, plan, failed=None, axis=axis)


def reentangle_stream(
    recovered: jax.Array, plan: EntanglePlan, stream: int, axis: int = 0
) -> jax.Array:
    """Recreate the lost entangled stream ``delta_stream`` from recovered d's.

    Used by SDC detection and by roll-forward repair of persisted entangled
    state: ``delta_m = S_l{d_{m-1}} + d_m``.
    """
    d = jnp.moveaxis(recovered, axis, 0) if axis != 0 else recovered
    m = stream % plan.M
    return jnp.left_shift(d[(m - 1) % plan.M], plan.l) + d[m]


# ----------------------------------------------------------------------------
# numpy int64 oracle (CPU reference; used by tests and kernels/ref.py)
# ----------------------------------------------------------------------------

def disentangle_oracle_np(
    delta: np.ndarray, plan: EntanglePlan, failed: Optional[int] = None
) -> np.ndarray:
    """Reference disentanglement in numpy int64 (temp mode 'int64np')."""
    M, l = plan.M, plan.l
    r = 0 if failed is None else int(failed) % M
    B = (M - 1) * l
    sign = -1 if (M % 2) else 1
    q = (r + M - 1) % M

    d64 = delta.astype(np.int64)
    t = d64[(r + 1) % M].copy()
    for m in range(2, M):
        t = t << l
        t = (t - d64[(r + m) % M]) if (m % 2 == 0) else (t + d64[(r + m) % M])
    # sign-extended low B bits
    t_lo = (t << (64 - B)) >> (64 - B)
    d_q = sign * t_lo
    d_r = (t - t_lo) >> B

    out = [None] * M
    out[r], out[q] = d_r, d_q
    for m in range(1, M - 1):
        idx = (r + m) % M
        out[idx] = d64[idx] - (out[(r + m - 1) % M] << l)
    return np.stack(out, axis=0).astype(np.int64)
