"""Fail-stop protection engine: one interface over all recovery families.

The paper positions numerical entanglement as a *third family* of fail-stop
recovery next to checksum-ABFT and modular redundancy (MR). This module
exposes all three (plus unprotected passthrough) behind one functional API so
the framework, benchmarks and tests can switch families via config — exactly
the comparison the paper's Fig. 2 makes.

A fail-stop is modeled as a stream index whose computation never returned
(crash or deadline miss — paper Sec. I treats both identically). The engine
replaces the lost stream's buffer with garbage before recovery to prove the
recovery path never reads it.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.checksum import attach_checksum, recover_from_checksum
from repro.core.entangle import disentangle, entangle
from repro.core.lsb_ops import LSBOp, apply_streams, get_op
from repro.core.plan import EntanglePlan, make_plan

Array = jax.Array

# poison for lost streams. A plain Python int, NOT a jnp scalar: modules
# are sometimes first imported inside a jit trace (lazy imports in traced
# step functions), where a module-level jnp constant would be created as a
# tracer of that trace and leak into every later trace.
GARBAGE = -0x5A5A5A5A


@dataclasses.dataclass(frozen=True)
class FTConfig:
    """Fault-tolerance selection for a protected computation."""

    mode: str = "entangle"  # none | entangle | checksum | mr
    M: int = 4
    w: int = 32
    headroom_bits: int = 0

    def plan(self) -> EntanglePlan:
        return make_plan(self.M, self.w, self.headroom_bits)

    @property
    def extra_streams(self) -> int:
        """Cores beyond M required by this family (paper Sec. II)."""
        return {"none": 0, "entangle": 0, "checksum": 1, "mr": None}.get(
            self.mode, 0
        ) if self.mode != "mr" else self.M


@dataclasses.dataclass(frozen=True)
class FTReport:
    mode: str
    failed: Optional[int]
    recovered: bool


def _poison(x: Array, stream: int) -> Array:
    return x.at[stream].set(GARBAGE)


def run_protected(
    op_name: str,
    c: Array,
    g: Optional[Array],
    cfg: FTConfig,
    failed: Optional[int] = None,
) -> tuple[Array, FTReport]:
    """Run op over M streams under the configured protection family.

    Args:
      op_name: key into the LSB op registry.
      c: [M, ...] integer input streams.
      g: kernel/operand (op-specific; None for identity).
      cfg: protection family config.
      failed: injected fail-stop stream index (None = healthy run). For
        mode='checksum' the index may equal M (the checksum core itself).

    Returns:
      ([M, ...] recovered true outputs, report). mode='none' with a failure
      returns poisoned outputs and recovered=False — the failure-intolerant
      baseline semantics.
    """
    op: LSBOp = get_op(op_name)
    M = cfg.M
    if c.shape[0] != M:
        raise ValueError(f"expected {M} streams, got {c.shape[0]}")

    if cfg.mode == "none":
        d = apply_streams(op, c, g)
        if failed is not None:
            return _poison(d, failed), FTReport("none", failed, False)
        return d, FTReport("none", None, True)

    if cfg.mode == "entangle":
        plan = cfg.plan()
        eps = entangle(c, plan)
        ge = op.kernel_for_entangled(g, plan)
        delta = apply_streams(op, eps, ge)
        if failed is not None:
            delta = _poison(delta, failed)
        d = disentangle(delta, plan, failed=failed)
        return d, FTReport("entangle", failed, True)

    if cfg.mode == "checksum":
        cr = attach_checksum(c)
        out = apply_streams(op, cr, g)
        if failed is not None:
            out = _poison(out, failed)
        d = recover_from_checksum(out, op, g, failed)
        return d, FTReport("checksum", failed, True)

    if cfg.mode == "mr":
        # Dual modular redundancy: every stream computed twice (2M cores);
        # a fail-stop in copy A of stream f is served by copy B.
        both = jnp.concatenate([c, c], axis=0)
        out = apply_streams(op, both, g)
        if failed is not None:
            out = _poison(out, failed)
        d = jnp.where(
            (jnp.arange(M) == (failed if failed is not None else -1))[
                (...,) + (None,) * (out.ndim - 1)
            ],
            out[M:],
            out[:M],
        )
        return d, FTReport("mr", failed, True)

    raise ValueError(f"unknown ft mode {cfg.mode!r}")
