"""Dual-word 64-bit integer arithmetic built from 32-bit lanes.

TPU has no fast 64-bit integer path, but the disentanglement temporary of
paper eq. (16) needs up to ``2w`` bits (43 bits for the canonical
``w=32, M=3, l=11, k=10`` configuration). Paper Remark 1 observes the
temporary can be carried as two ``w``-bit words; this module is that
realization: a value ``v`` is represented as ``(hi, lo)`` with

    v = hi * 2**32 + lo,   hi: int32 (signed),  lo: uint32 (unsigned)

Only the operations required by the disentanglement recurrence are provided:
widening, left shift, subtraction, signed low-bit extraction and exact
arithmetic right shift. All ops are elementwise, jit/vmap/shard_map-safe and
lower to plain VPU integer lanes on TPU.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class DualWord(NamedTuple):
    hi: jax.Array  # int32, signed high word
    lo: jax.Array  # uint32, unsigned low word


def _bitcast_i32(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x, jnp.int32)


def _bitcast_u32(x: jax.Array) -> jax.Array:
    return jax.lax.bitcast_convert_type(x, jnp.uint32)


def widen(x: jax.Array) -> DualWord:
    """Sign-extend a 32-bit signed value into a dual word."""
    x = x.astype(jnp.int32)
    return DualWord(hi=jnp.right_shift(x, 31), lo=_bitcast_u32(x))


def shl(d: DualWord, l: int) -> DualWord:
    """Left shift by a static 0 <= l < 32."""
    if l == 0:
        return d
    carry = _bitcast_i32(jnp.right_shift(d.lo, jnp.uint32(32 - l)))
    hi = jnp.bitwise_or(jnp.left_shift(d.hi, l), carry)
    lo = jnp.left_shift(d.lo, jnp.uint32(l))
    return DualWord(hi=hi, lo=lo)


def sub(a: DualWord, b: DualWord) -> DualWord:
    """a - b with borrow propagation (wrapping mod 2**64)."""
    lo = a.lo - b.lo
    borrow = (a.lo < b.lo).astype(jnp.int32)
    hi = a.hi - b.hi - borrow
    return DualWord(hi=hi, lo=lo)


def add(a: DualWord, b: DualWord) -> DualWord:
    """a + b with carry propagation (wrapping mod 2**64)."""
    lo = a.lo + b.lo
    carry = (lo < a.lo).astype(jnp.int32)
    hi = a.hi + b.hi + carry
    return DualWord(hi=hi, lo=lo)


def extract_low_signed(d: DualWord, bits: int) -> jax.Array:
    """Low ``bits`` (1 <= bits <= 31) of ``d`` as a sign-extended int32."""
    assert 1 <= bits <= 31, bits
    x = _bitcast_i32(jnp.left_shift(d.lo, jnp.uint32(32 - bits)))
    return jnp.right_shift(x, 32 - bits)


def shr_exact_to_i32(d: DualWord, bits: int) -> jax.Array:
    """(d >> bits) for a value known to fit int32 after the shift.

    ``bits`` is static, 0 <= bits <= 31. Exact for negative multiples of
    ``2**bits`` as well (two's complement arithmetic shift semantics).
    """
    assert 0 <= bits <= 31, bits
    if bits == 0:
        return _bitcast_i32(d.lo)
    low = jnp.right_shift(d.lo, jnp.uint32(bits))  # logical
    high = jnp.left_shift(_bitcast_u32(d.hi), jnp.uint32(32 - bits))
    return _bitcast_i32(jnp.bitwise_or(low, high))
