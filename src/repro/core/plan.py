"""Entanglement parameter planning — paper Sec. III.B, Table I.

Chooses the shift amount ``l`` and headroom ``k`` for ``M``-stream numerical
entanglement under a ``w``-bit integer representation, subject to the paper's
overflow constraint (eq. 12)::

    (M - 1) * l + k <= w,   k <= l,   l >= 1, k >= 1

The objective reproduced from Table I is the *output* bitwidth
``(M - 2) * l + k`` (ties broken toward larger ``k``); the supported output
dynamic range is eq. (13)::

    |d| <= 2^((M-3)l + k) * (2^(l-1) - 1)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class EntanglePlan:
    """Static parameters of one entanglement configuration.

    Attributes:
      M: number of jointly-entangled streams (>= 3).
      w: logical integer width of each stream element, in bits (8/16/32).
      l: arithmetic-shift amount of the superposed stream (paper ``l``).
      k: headroom bits (paper ``k``).
      temp: implementation of the 2w-bit temporary of eq. (16):
        ``'int32'``   — plain int32 container (valid when (2M-3)l+k+1 <= 32),
        ``'dualword'``— two 32-bit words (hi:int32, lo:uint32); TPU-native
                        realization of paper Remark 1,
        ``'int64np'`` — numpy int64 oracle (CPU reference only).
    """

    M: int
    w: int
    l: int
    k: int
    temp: str = "int32"

    def __post_init__(self):
        if self.M < 3:
            raise ValueError(f"entanglement needs M >= 3 streams, got M={self.M}")
        if not (1 <= self.k <= self.l):
            raise ValueError(f"need 1 <= k <= l, got l={self.l} k={self.k}")
        if (self.M - 1) * self.l + self.k > self.w:
            raise ValueError(
                f"overflow constraint (M-1)l+k <= w violated: "
                f"({self.M}-1)*{self.l}+{self.k} > {self.w}"
            )
        if self.temp not in ("int32", "dualword", "int64np"):
            raise ValueError(f"unknown temp mode {self.temp!r}")
        if self.temp == "int32" and self.temp_bits > 32:
            raise ValueError(
                f"temp mode 'int32' needs (2M-3)l+k+1 <= 32, got {self.temp_bits}"
            )

    # ---- derived quantities -------------------------------------------------

    @property
    def output_bits(self) -> int:
        """Usable output bitwidth, Table I column '(M-2)l + k'."""
        return (self.M - 2) * self.l + self.k

    @property
    def temp_bits(self) -> int:
        """Bits needed by the eq. (16) temporary: (2M-3)l + k + 1."""
        return (2 * self.M - 3) * self.l + self.k + 1

    @property
    def max_output_magnitude(self) -> int:
        """Largest |d| any LSB output may take — paper eq. (13)."""
        return (1 << ((self.M - 3) * self.l + self.k)) * ((1 << (self.l - 1)) - 1)

    @property
    def max_output_magnitude_tight(self) -> int:
        """Exact sufficient output bound (beyond-paper).

        Eq. (13) is conservative and collapses to 0 at ``l == 1`` (e.g. the
        M=32 Table I row). The scheme only needs:
          (a) entangled outputs fit w bits:  (2^l + 1) * D <= 2^(w-1) - 1
          (b) low-word extraction:           D <= 2^((M-1)l - 1) - 1
          (c) d_temp fits its container:     (2^((M-1)l) + 1) * D <= 2^(cap-1) - 1
        """
        cap = 32 if self.temp == "int32" else 64
        a = ((1 << (self.w - 1)) - 1) // ((1 << self.l) + 1)
        b = (1 << ((self.M - 1) * self.l - 1)) - 1
        c = ((1 << (cap - 1)) - 1) // ((1 << ((self.M - 1) * self.l)) + 1)
        return min(a, b, c)

    @property
    def container_bits(self) -> int:
        """Bits of the integer container used to store streams on device."""
        return 32 if self.w > 16 else (16 if self.w > 8 else 8)

    def headroom_for_reduction(self, depth: int) -> int:
        """Bits of |d| budget consumed by an exact sum of ``depth`` terms."""
        return max(0, math.ceil(math.log2(max(depth, 1))))


def plan_lk(M: int, w: int = 32, headroom_bits: int = 0) -> tuple[int, int]:
    """Choose (l, k) reproducing paper Table I.

    Maximizes output bitwidth (M-2)l + k subject to eq. (12), k <= l; ties
    broken toward larger k (matches every Table I row). ``headroom_bits``
    shrinks the effective width budget — used when the LSB op is a deep
    reduction (e.g. an R-term dot product or cross-replica gradient sum needs
    ceil(log2 R) extra bits of output headroom).
    """
    w_eff = w - headroom_bits
    best: Optional[tuple[int, int]] = None
    best_key = None
    for l in range(1, w_eff + 1):
        k = min(l, w_eff - (M - 1) * l)
        if k < 1:
            continue
        key = ((M - 2) * l + k, k)
        if best_key is None or key > best_key:
            best_key, best = key, (l, k)
    if best is None:
        raise ValueError(f"no feasible (l,k) for M={M}, w={w}, headroom={headroom_bits}")
    return best


def make_plan(
    M: int,
    w: int = 32,
    headroom_bits: int = 0,
    temp: Optional[str] = None,
) -> EntanglePlan:
    """Plan (l,k) and pick the widest-compatible temp mode automatically."""
    l, k = plan_lk(M, w, headroom_bits)
    if temp is None:
        temp_bits = (2 * M - 3) * l + k + 1
        temp = "int32" if temp_bits <= 32 else "dualword"
    return EntanglePlan(M=M, w=w, l=l, k=k, temp=temp)


def checksum_output_bits(M: int, w: int = 32) -> int:
    """Output bitwidth of the checksum-based method, Table I right column."""
    return w - math.ceil(math.log2(M))


def container_dtype(plan: EntanglePlan):
    """numpy dtype of the on-device stream container."""
    return {8: np.int8, 16: np.int16, 32: np.int32}[plan.container_bits]
