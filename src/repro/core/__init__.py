"""Core library: numerical entanglement for fail-stop mitigation (the paper's
contribution), plus the checksum-ABFT / modular-redundancy baselines it is
compared against, SDC detection, and the float<->fixed-point bridge used to
apply the technique inside the LM framework."""
from repro.core.plan import (
    EntanglePlan,
    checksum_output_bits,
    container_dtype,
    make_plan,
    plan_lk,
)
from repro.core.entangle import (
    disentangle,
    disentangle_oracle_np,
    entangle,
    entangle_kernel_addsub,
    extract,
    reentangle_stream,
)
from repro.core.checksum import (
    attach_checksum,
    make_checksum_stream,
    recover_from_checksum,
)
from repro.core.failstop import FTConfig, FTReport, run_protected
from repro.core.fixed_point import dequantize, fit_scale, quantize
from repro.core.lsb_ops import OPS, LSBOp, apply_streams, get_op

__all__ = [
    "EntanglePlan",
    "FTConfig",
    "FTReport",
    "LSBOp",
    "OPS",
    "apply_streams",
    "attach_checksum",
    "checksum_output_bits",
    "container_dtype",
    "dequantize",
    "disentangle",
    "disentangle_oracle_np",
    "entangle",
    "entangle_kernel_addsub",
    "extract",
    "fit_scale",
    "get_op",
    "make_checksum_stream",
    "make_plan",
    "plan_lk",
    "quantize",
    "recover_from_checksum",
    "reentangle_stream",
]
