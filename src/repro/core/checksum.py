"""Checksum-based ABFT baseline — paper Sec. II.A, eq. (3)-(5).

One additional stream ``r = sum_m c_m`` is created and processed alongside
the M originals on an (M+1)-th core. Any single fail-stop among the M+1
streams is recovered:

  * failed data stream m:  d_m = e - sum_{m' != m} d_m'   (op-corrected)
  * failed checksum stream: nothing to recover (outputs unaffected).

This is the comparison point for the paper's Fig. 2 / Sec. IV overhead
analysis: the checksum stream re-runs the FULL LSB op (cost ~ 1/M of total)
whereas entanglement's overhead is O(M·N) regardless of the op.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.lsb_ops import LSBOp

Array = jax.Array


def make_checksum_stream(c: Array, axis: int = 0) -> Array:
    """r_n = sum_m c_{m,n} (eq. 4). Caller owns the reduced dynamic range
    budget (w - ceil(log2 M) bits, Table I)."""
    return jnp.sum(c, axis=axis)


def attach_checksum(c: Array, axis: int = 0) -> Array:
    """Stack the checksum stream as stream index M (eq. 5 left-hand side)."""
    r = make_checksum_stream(c, axis=axis)
    return jnp.concatenate([c, jnp.expand_dims(r, axis)], axis=axis)


def recover_from_checksum(
    outputs: Array,
    op: LSBOp,
    g: Optional[Array],
    failed: Optional[int],
    axis: int = 0,
) -> Array:
    """Recover the M true outputs from M+1 streams with stream ``failed`` lost.

    Args:
      outputs: [M+1, ...] op outputs, last stream is the checksum stream's
        output ``e = op(r, g)``.
      failed: lost stream index in [0, M] (M = checksum stream) or None.

    Returns:
      [M, ...] recovered outputs.
    """
    if axis != 0:
        outputs = jnp.moveaxis(outputs, axis, 0)
    Mp1 = outputs.shape[0]
    M = Mp1 - 1
    d, e = outputs[:M], outputs[M]
    if failed is None or failed == M:
        res = d
    else:
        f = int(failed)
        others = jnp.sum(d, axis=0) - d[f]
        # e == op-corrected sum of all d's; invert for the missing one.
        # checksum_prediction(d_full) = sum(d_full) + corr(g, M); so
        # d_f = e - corr - others.
        corr = op.checksum_prediction(jnp.zeros_like(d), g, M)
        d_f = e - corr - others
        res = d.at[f].set(d_f)
    if axis != 0:
        res = jnp.moveaxis(res, 0, axis)
    return res
