"""Silent-data-corruption detection over entangled outputs.

Paper Remark 4 notes the entangled representation can also detect SDCs
("we plan to explore this aspect in future work") — implemented here,
beyond-paper. With M entangled outputs but only M-1 needed for extraction,
each output position carries exactly one redundant w-bit constraint:

    predict(delta_r) := S_l{d_hat_{r-1}} + d_hat_r,  d_hat := disentangle w/o r

A healthy position satisfies predict(delta_r) == delta_r for every r; any
single-stream corruption at a position violates it. One parity cannot
*localize* the corrupted stream (that needs recomputation of one candidate
stream, or coinciding-position-free corruption as the paper requires), so the
API reports detection masks and an optional localization via the holdout
consensus: if exactly one holdout r yields a self-consistent prediction set,
r is the corrupted stream.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.entangle import disentangle, reentangle_stream
from repro.core.plan import EntanglePlan

Array = jax.Array


def detect(delta: Array, plan: EntanglePlan) -> Array:
    """Boolean mask (per output position) of detected corruption.

    True where ANY of the M cyclic redundancy constraints is violated.
    """
    bad = None
    for r in range(plan.M):
        d = disentangle(delta, plan, failed=r)
        pred = reentangle_stream(d, plan, stream=r)
        viol = pred != delta[r]
        bad = viol if bad is None else (bad | viol)
    return bad


def localize(delta: Array, plan: EntanglePlan) -> Array:
    """Best-effort per-position corrupted-stream index (-1 = clean/ambiguous).

    A single parity per position guarantees *detection* only; localization
    here is heuristic: the recovery holding out the truly-corrupted stream j
    yields outputs inside the eq. (13) range contract, while holdouts r != j
    propagate the corruption into the recovered values, typically blowing
    them out of range (a corruption of magnitude >= 2^l in the low bits is
    amplified by up to 2^{(M-1)l} in the wrong holdout). Positions where the
    range test does not single out one stream return -1; callers then fall
    back to recomputing one stream (still cheaper than full recomputation).
    """
    M = plan.M
    bad = detect(delta, plan)
    # Corruption in the holdout stream never enters recovery, so the true
    # holdout yields the (small, plausible) original values; wrong holdouts
    # amplify the error by up to 2^{(M-1)l}. Blame the magnitude minimizer.
    maxabs = []
    for r in range(M):
        d = disentangle(delta, plan, failed=r)
        maxabs.append(jnp.max(jnp.abs(d).astype(jnp.uint32), axis=0))
    scores = jnp.stack(maxabs)  # [M, ...]
    blame = jnp.argmin(scores, axis=0)
    return jnp.where(bad, blame, -1)
