"""Registry of Linear / Sesquilinear / Bijective (LSB) operations — paper eq. (2).

Each :class:`LSBOp` knows how to
  * apply itself to a (possibly entangled) stream,
  * prepare its kernel for entangled execution (ops in {+, -} need the kernel
    self-entangled, paper footnote 3),
  * combine per-stream outputs into the checksum-stream prediction used by
    the checksum-ABFT baseline (Sec. II.A), including the op-specific
    correction for ops that are affine rather than linear in the stream
    (e.g. ``add``: e = sum_m d_m - (M-1) g).

Only *data-independent* ops qualify (paper footnote 2): permutations use
fixed index sets; the MoE router's data-dependent top-k, for instance, is
explicitly out of scope (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.entangle import entangle_kernel_addsub
from repro.core.plan import EntanglePlan

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class LSBOp:
    """A data-independent linear/sesquilinear/bijective stream operation.

    Attributes:
      name: registry key.
      apply: (stream, kernel) -> output stream; must be linear in the stream
        (for fixed kernel) or a fixed bijection.
      needs_kernel_entangled: True for op in {+, -} (footnote 3).
      checksum_combine: maps (stacked outputs d[M, ...], kernel, M) to the
        value the checksum stream's output must equal; defaults to sum_m d_m.
      out_len: N_out given (N_in, kernel) — used by harnesses to presize.
    """

    name: str
    apply: Callable[[Array, Optional[Array]], Array]
    needs_kernel_entangled: bool = False
    checksum_combine: Optional[Callable[[Array, Optional[Array], int], Array]] = None

    def kernel_for_entangled(self, g: Optional[Array], plan: EntanglePlan):
        if g is not None and self.needs_kernel_entangled:
            return entangle_kernel_addsub(g, plan)
        return g

    def checksum_prediction(self, d: Array, g: Optional[Array], M: int) -> Array:
        if self.checksum_combine is not None:
            return self.checksum_combine(d, g, M)
        return jnp.sum(d, axis=0)


def _scale(c, g):
    return c * g


def _add(c, g):
    return c + g


def _sub(c, g):
    return c - g


def _dot(c, g):
    return jnp.dot(c, g, preferred_element_type=jnp.int32)


def _outer(c, g):
    return jnp.einsum("i,j->ij", c, g).astype(jnp.int32)


def _int_conv(c, g, flip: bool):
    """Exact integer 'full' convolution/correlation. jnp.convolve promotes
    int32 to float32 (exact only below 2^24 — silently corrupting entangled
    values); lax.conv with preferred_element_type keeps the int32 ring."""
    nk = g.shape[-1]
    kern = jnp.flip(g) if flip else g
    out = jax.lax.conv_general_dilated(
        c[None, None, :].astype(jnp.int32),
        kern[None, None, :].astype(jnp.int32),
        window_strides=(1,),
        padding=[(nk - 1, nk - 1)],
        preferred_element_type=jnp.int32,
    )
    return out[0, 0]


def _conv_full(c, g):
    return _int_conv(c, g, flip=True)


def _xcorr_full(c, g):
    return _int_conv(c, g, flip=False)


def _circular_conv(c, g):
    n = c.shape[-1]
    gg = jnp.zeros(n, dtype=c.dtype).at[: g.shape[-1]].set(g)
    idx = (jnp.arange(n)[:, None] - jnp.arange(n)[None, :]) % n
    return jnp.dot(gg[idx].astype(jnp.int32).T, c.astype(jnp.int32))


def _permute(c, g):
    # g is a fixed index set (bijection I -> G): out[i] = c[g[i]]
    return jnp.take(c, g, axis=-1)


def _identity(c, g):
    del g
    return c


OPS: Dict[str, LSBOp] = {
    op.name: op
    for op in [
        LSBOp("scale", _scale),
        LSBOp(
            "add",
            _add,
            needs_kernel_entangled=True,
            checksum_combine=lambda d, g, M: jnp.sum(d, axis=0)
            - 0 * d[0],  # e = (sum_m c_m) + g = sum_m d_m - (M-1) g
        ),
        LSBOp("sub", _sub, needs_kernel_entangled=True),
        LSBOp("dot", _dot),
        LSBOp("outer", _outer),
        LSBOp("conv", _conv_full),
        LSBOp("xcorr", _xcorr_full),
        LSBOp("circconv", _circular_conv),
        LSBOp("permute", _permute),
        LSBOp("identity", _identity),
    ]
}

# checksum-stream corrections for affine ops: the checksum input r = sum_m c_m
# goes through the op once, so e = op(r, g). For linear-in-stream ops,
# op(sum c, g) = sum op(c, g); for add/sub it differs by (M-1)*g.
OPS["add"] = dataclasses.replace(
    OPS["add"],
    checksum_combine=lambda d, g, M: jnp.sum(d, axis=0) - (M - 1) * g,
)
OPS["sub"] = dataclasses.replace(
    OPS["sub"],
    needs_kernel_entangled=True,
    checksum_combine=lambda d, g, M: jnp.sum(d, axis=0) + (M - 1) * g,
)


def get_op(name: str) -> LSBOp:
    try:
        return OPS[name]
    except KeyError:
        raise KeyError(f"unknown LSB op {name!r}; known: {sorted(OPS)}") from None


def apply_streams(op: LSBOp, c: Array, g: Optional[Array]) -> Array:
    """vmap an LSB op over the leading stream axis."""
    if g is None:
        return jax.vmap(lambda x: op.apply(x, None))(c)
    return jax.vmap(lambda x: op.apply(x, g))(c)
