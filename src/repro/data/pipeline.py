"""Token-shard storage protected by numerical entanglement.

The paper notes inputs "can also be left in their native state (stored in
memory)" under op = identity — i.e. entanglement doubles as an erasure code
for data at rest with zero extra streams. This store writes each token-shard
group as M entangled files; ANY single missing/corrupt file in a group is
recovered on read by disentanglement (the storage-failure analogue of a
fail-stop). Background prefetch keeps the trainer fed.
"""
from __future__ import annotations

import json
import pathlib
import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.core.entangle import disentangle_oracle_np
from repro.core.plan import EntanglePlan, make_plan


class TokenShardStore:
    def __init__(self, root: str, M: int = 4, w: int = 32):
        self.root = pathlib.Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.plan = make_plan(M, w)

    def _entangle_np(self, blocks: np.ndarray) -> np.ndarray:
        l = self.plan.l
        return ((np.roll(blocks, 1, 0).astype(np.int64) << l) + blocks).astype(
            np.int32
        )

    def write_group(self, name: str, tokens: np.ndarray) -> list[pathlib.Path]:
        """Write tokens (any int array) as M entangled shard files + manifest."""
        M = self.plan.M
        flat = tokens.reshape(-1).astype(np.int32)
        pad = (-flat.size) % M
        flat = np.pad(flat, (0, pad))
        blocks = flat.reshape(M, -1)
        eps = self._entangle_np(blocks)
        paths = []
        for m in range(M):
            p = self.root / f"{name}.shard{m}.npy"
            np.save(p, eps[m])
            paths.append(p)
        manifest = {
            "name": name, "M": M, "w": self.plan.w, "l": self.plan.l,
            "k": self.plan.k, "orig_size": int(tokens.size),
            "shape": list(tokens.shape), "pad": int(pad),
        }
        (self.root / f"{name}.json").write_text(json.dumps(manifest))
        return paths

    def read_group(self, name: str) -> np.ndarray:
        """Read a group, surviving loss of ANY single shard file."""
        man = json.loads((self.root / f"{name}.json").read_text())
        M = man["M"]
        shards, missing = [], []
        for m in range(M):
            p = self.root / f"{name}.shard{m}.npy"
            try:
                shards.append(np.load(p))
            except (FileNotFoundError, ValueError):
                shards.append(None)
                missing.append(m)
        if len(missing) > 1:
            raise IOError(f"group {name}: {len(missing)} shards lost; "
                          f"single-failure code can recover only one")
        failed: Optional[int] = missing[0] if missing else None
        proto = next(s for s in shards if s is not None)
        eps = np.stack([s if s is not None else np.zeros_like(proto) for s in shards])
        plan = EntanglePlan(M=M, w=man["w"], l=man["l"], k=man["k"],
                            temp="int64np")
        blocks = disentangle_oracle_np(eps, plan, failed)
        flat = blocks.reshape(-1)
        n = int(np.prod(man["shape"]))
        return flat[:n].astype(np.int32).reshape(man["shape"])


class Prefetcher:
    """Background-thread prefetch of an iterator (depth-bounded queue)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
