"""Synthetic LM data pipeline: deterministic, seekable, shard-aware.

Generates token streams with enough structure for a ~100M model to visibly
learn (repeating n-gram processes seeded per document), so the end-to-end
example's loss curve is meaningful, while remaining fully offline.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 32000
    seq_len: int = 512
    batch_size: int = 8
    seed: int = 0
    order: int = 3  # markov order of the synthetic process


class SyntheticLM:
    """Deterministic synthetic corpus: mixture of per-document Markov chains.

    ``batch(step)`` is pure in (config, step) — any worker can regenerate any
    batch, which is what makes checkpoint-restart and elastic re-sharding
    trivially consistent (the data pipeline is stateless)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        k = min(64, v)
        # order-1 Markov with biased per-state emission pools: each state
        # emits from its own small token pool with a Zipf-ish profile, and
        # the next state is a direct function of the emitted token — so
        # bigram statistics alone already cut the conditional entropy from
        # ln(V) to ~ln(pool)/2, giving a loss curve that visibly bends
        # within a handful of smoke-test steps
        pool = min(17, v)
        self._emit = rng.integers(0, v, size=(k, pool)).astype(np.int32)
        # Zipf-ish index profile: index j is emitted with weight 1/(j+1)
        w = 1.0 / np.arange(1, pool + 1)
        self._cdf = np.cumsum(w / w.sum())
        self._cdf[-1] = 1.0  # float cumsum can land below 1.0; a uniform
        # draw in that gap would searchsorted past the last pool index

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(hash((cfg.seed, step)) % (2**31))
        B, T = cfg.batch_size, cfg.seq_len
        k = self._emit.shape[0]
        state = rng.integers(0, k, size=B)
        pick = np.searchsorted(self._cdf, rng.random((B, T)))
        toks = np.empty((B, T), np.int32)
        for t in range(T):
            toks[:, t] = self._emit[state, pick[:, t]]
            state = toks[:, t] % k
        return {
            "tokens": toks,
            "loss_mask": np.ones((B, T), np.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
