"""Synthetic LM data pipeline: deterministic, seekable, shard-aware.

Generates token streams with enough structure for a ~100M model to visibly
learn (repeating n-gram processes seeded per document), so the end-to-end
example's loss curve is meaningful, while remaining fully offline.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int = 32000
    seq_len: int = 512
    batch_size: int = 8
    seed: int = 0
    order: int = 3  # markov order of the synthetic process


class SyntheticLM:
    """Deterministic synthetic corpus: mixture of per-document Markov chains.

    ``batch(step)`` is pure in (config, step) — any worker can regenerate any
    batch, which is what makes checkpoint-restart and elastic re-sharding
    trivially consistent (the data pipeline is stateless)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        k = min(64, v)
        # shared low-rank transition structure
        self._emit = rng.integers(0, v, size=(k, 257)).astype(np.int32)

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(hash((cfg.seed, step)) % (2**31))
        B, T = cfg.batch_size, cfg.seq_len
        state = rng.integers(0, self._emit.shape[0], size=B)
        noise = rng.integers(0, 257, size=(B, T))
        toks = np.empty((B, T), np.int32)
        for t in range(T):
            toks[:, t] = self._emit[state, noise[:, t]]
            state = (state * 31 + toks[:, t]) % self._emit.shape[0]
        return {
            "tokens": toks,
            "loss_mask": np.ones((B, T), np.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
