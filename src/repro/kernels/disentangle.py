"""Pallas TPU kernel: disentanglement / fail-stop recovery (paper eq. 16-19).

Fuses the Horner-form telescoping sum, the dual-word (2w-bit as 2x32-bit,
paper Remark 1) arithmetic, the bit-field extraction of d_r / d_q and the
eq. (19) recovery chain into one VPU pass over VMEM tiles — the entire
recovery is shifts/adds, exactly the paper's "additions and arithmetic
shifts" claim, with no HBM round-trips between steps.

The failed-stream index r is static (known at recovery dispatch time).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import wideint
from repro.core.plan import EntanglePlan


def _disentangle_kernel(delta_ref, out_ref, *, plan: EntanglePlan, r: int):
    M, l = plan.M, plan.l
    B = (M - 1) * l
    sign = -1 if (M % 2) else 1
    q = (r + M - 1) % M
    delta = delta_ref[...]  # [M, block_n] int32

    deltas = [delta[(r + 1 + m) % M] for m in range(M - 1)]
    if plan.temp == "dualword":
        t = wideint.widen(deltas[0])
        for j, d in enumerate(deltas[1:], start=2):
            t = wideint.shl(t, l)
            t = (
                wideint.sub(t, wideint.widen(d))
                if (j % 2 == 0)
                else wideint.add(t, wideint.widen(d))
            )
        t_lo = wideint.extract_low_signed(t, B)
        d_q = (sign * t_lo).astype(jnp.int32)
        d_r = wideint.shr_exact_to_i32(wideint.sub(t, wideint.widen(t_lo)), B)
    else:
        t = deltas[0]
        for j, d in enumerate(deltas[1:], start=2):
            t = jnp.left_shift(t, l)
            t = (t - d) if (j % 2 == 0) else (t + d)
        shift = 32 - B
        t_lo = jnp.right_shift(jnp.left_shift(t, shift), shift)
        d_q = (sign * t_lo).astype(jnp.int32)
        d_r = jnp.right_shift(t - t_lo, B)

    out = [None] * M
    out[r], out[q] = d_r, d_q
    for m in range(1, M - 1):  # eq. (19)
        idx = (r + m) % M
        out[idx] = delta[idx] - jnp.left_shift(out[(r + m - 1) % M], l)
    out_ref[...] = jnp.stack(out, axis=0)


@functools.partial(
    jax.jit, static_argnames=("plan", "r", "block_n", "interpret")
)
def disentangle_pallas(
    delta: jax.Array,
    *,
    plan: EntanglePlan,
    r: int = 0,
    block_n: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """Recover all M outputs from [M, N] entangled outputs, never reading
    stream r. N must be a multiple of block_n (ops.py pads/unpads)."""
    M, N = delta.shape
    assert M == plan.M
    grid = (N // block_n,)
    return pl.pallas_call(
        functools.partial(_disentangle_kernel, plan=plan, r=r % M),
        grid=grid,
        in_specs=[pl.BlockSpec((M, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((M, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(delta)
