"""Pallas TPU kernel: standalone disentanglement / fail-stop recovery.

The codec math (paper eq. 16-19: Horner telescoping, dual-word temporary
per Remark 1, bit-field split, eq. 19 chain) lives in
:mod:`repro.kernels.codec` and is shared with the fused GEMM/conv1d
epilogues. This kernel is the *separate-pass* form of it — one VPU sweep
over [M, block_n] VMEM tiles — kept for entangled data that arrives from
outside a fused kernel (persisted entangled state, cross-host streams) and
as the three-pass baseline the fused kernels are benchmarked against.

The failed-stream index r is static (known at recovery dispatch time).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.plan import EntanglePlan
from repro.kernels.codec import disentangle_block


def _disentangle_kernel(delta_ref, out_ref, *, plan: EntanglePlan, r: int):
    out_ref[...] = disentangle_block(delta_ref[...], plan, r)


@functools.partial(
    jax.jit, static_argnames=("plan", "r", "block_n", "interpret")
)
def disentangle_pallas(
    delta: jax.Array,
    *,
    plan: EntanglePlan,
    r: int = 0,
    block_n: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """Recover all M outputs from [M, N] entangled outputs, never reading
    stream r. N must be a multiple of block_n (ops.py pads/unpads)."""
    M, N = delta.shape
    assert M == plan.M
    grid = (N // block_n,)
    return pl.pallas_call(
        functools.partial(_disentangle_kernel, plan=plan, r=r % M),
        grid=grid,
        in_specs=[pl.BlockSpec((M, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((M, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(delta)
