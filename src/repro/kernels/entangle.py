"""Pallas TPU kernel: numerical entanglement (paper eq. 6/14/15).

The paper entangles streams with AVX2 SIMD "as data within each input stream
is being read". The TPU analogue: an elementwise VPU kernel tiled into VMEM.
The M-stream axis is small and fully resident per tile; the sample axis is
tiled in lane-aligned blocks. Layout is [M, N] with N the flattened sample
axis, blocked (M, block_n); block_n is a multiple of 128 (lane width) and the
default 8*128 fills one (8, 128) VREG tile per stream row.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _entangle_kernel(c_ref, out_ref, *, M: int, l: int):
    c = c_ref[...]  # [M, block_n] int32
    prev = jnp.roll(c, 1, axis=0)  # row m holds c_{(m-1) mod M}
    out_ref[...] = jnp.left_shift(prev, l) + c


@functools.partial(jax.jit, static_argnames=("l", "block_n", "interpret"))
def entangle_pallas(
    c: jax.Array,
    *,
    l: int,
    block_n: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """Entangle [M, N] int32 streams; N must be a multiple of block_n
    (ops.py pads/unpads)."""
    M, N = c.shape
    grid = (N // block_n,)
    return pl.pallas_call(
        functools.partial(_entangle_kernel, M=M, l=l),
        grid=grid,
        in_specs=[pl.BlockSpec((M, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((M, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        interpret=interpret,
    )(c)
