"""Pallas TPU kernel: entangled depthwise causal conv1d, codec fully fused.

Convolution is the paper's experimental LSB op (Fig. 2): depthwise conv is
sesquilinear per stream, so ``conv(E c) = E conv(c)``. This kernel carries
that identity into the schedule — the M entangled streams share one weight
read and one fused pass:

  prologue  eps = (roll(x, 1) << l) + x      entangle-on-load (current tile
                                             AND its halo), in registers
  body      acc[m] = sum_j w[:, j] * win[m]  VPU taps, static unroll
  epilogue  d = disentangle(acc)             optional extract-at-flush

The M stream axis is fully resident per block (M is 3..8), so the cyclic
predecessor is a register roll — the operand is bound once per tile role.

Causality halo: each output tile of length ``bt`` needs ``K_f - 1``
trailing inputs of the previous tile. Pallas blocks are uniform, so the
input is bound a second time at index ``max(t-1, 0)`` for the halo, which
fetches a full extra tile per grid step (~2x input traffic) to use only
its trailing K_f - 1 columns. Accepted: conv input bytes are a small share
of a step's total traffic; carrying the previous tile's tail across grid
steps in VMEM scratch is the follow-up if a profile ever flags it (see
conv1d.py for the same trade-off on the unentangled kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.plan import EntanglePlan
from repro.kernels.codec import (PACK_LANES, disentangle_block,
                                 entangle_block, unpack_int8)


def _econv_kernel(
    x_cur_ref, x_prev_ref, w_ref, out_ref, *,
    plan: EntanglePlan, kf: int, fuse_epilogue: bool, r: int, packed: bool,
):
    t = pl.program_id(2)
    M, l = plan.M, plan.l

    eps_cur = entangle_block(x_cur_ref[:, 0], l)  # [M, bd, bt]
    eps_halo = entangle_block(x_prev_ref[:, 0, :, -(kf - 1):], l)
    eps_halo = jnp.where(t == 0, jnp.zeros_like(eps_halo), eps_halo)

    window = jnp.concatenate([eps_halo, eps_cur], axis=-1)  # [M, bd, bt+kf-1]
    bt = out_ref.shape[-1]
    acc = jnp.zeros(out_ref.shape[:1] + out_ref.shape[2:], jnp.int32)
    w = w_ref[...]
    if packed:  # [bd/4, kf] words -> [bd, kf] sign-extended lanes
        w = unpack_int8(w, axis=0)
    for j in range(kf):  # static unroll over taps
        acc += w[None, :, j : j + 1] * window[:, :, j : j + bt]

    if fuse_epilogue:
        acc = disentangle_block(acc, plan, r)
    out_ref[:, 0] = acc


@functools.partial(
    jax.jit,
    static_argnames=("plan", "fuse_epilogue", "failed", "bd", "bt",
                     "packed", "interpret"),
)
def entangled_conv1d_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    plan: EntanglePlan,
    fuse_epilogue: bool = False,
    failed: int = 0,
    bd: int = 128,
    bt: int = 512,
    packed: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Entangled depthwise causal conv: x [M, B, D, T] int32, w [D, K_f].

    Returns entangled conv outputs delta[m] = conv(E x)[m] when
    ``fuse_epilogue=False``, or the recovered true outputs
    d[m, b, d, t] = sum_j w[d, j] * x[m, b, d, t-K_f+1+j] when
    ``fuse_epilogue=True`` (extraction never reads stream ``failed``).
    With ``packed=True``, ``w`` is [D/4, K_f] packed int8 lanes (4 per
    int32 word along D), sign-extend-unpacked in registers per tile.
    D % bd == 0, T % bt == 0, 2 <= K_f <= bt (ops.py pads/unpads).
    """
    M, B, D, T = x.shape
    Dg, kf = w.shape
    assert D == (Dg * PACK_LANES if packed else Dg), (D, Dg, packed)
    assert 2 <= kf <= bt, (kf, bt)
    assert M == plan.M, (M, plan.M)
    grid = (B, D // bd, T // bt)
    bdg = bd // PACK_LANES if packed else bd
    return pl.pallas_call(
        functools.partial(
            _econv_kernel, plan=plan, kf=kf,
            fuse_epilogue=fuse_epilogue, r=failed % M, packed=packed,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((M, 1, bd, bt), lambda b, d, t: (0, b, d, t)),
            # predecessor tile (halo); same block index at t=0, masked above
            pl.BlockSpec(
                (M, 1, bd, bt),
                lambda b, d, t: (0, b, d, jnp.maximum(t - 1, 0)),
            ),
            pl.BlockSpec((bdg, kf), lambda b, d, t: (d, 0)),
        ],
        out_specs=pl.BlockSpec((M, 1, bd, bt), lambda b, d, t: (0, b, d, t)),
        out_shape=jax.ShapeDtypeStruct((M, B, D, T), jnp.int32),
        interpret=interpret,
    )(x, x, w)
