"""Pallas TPU kernel: integer GEMM with the FULL entanglement codec fused.

The paper's throughput claim (1.8-2.8% overhead, Fig. 2) rests on the codec
never being a separate memory sweep: entanglement is applied "as data within
each input stream is being read" and extraction as results are written. This
kernel honors both halves in one ``pallas_call``:

  prologue  eps = (roll(c, 1) << l) + c      entangle-on-load, in registers
  body      acc[m] += eps[m] @ g             MXU, int32 accumulate in VMEM
  epilogue  d = disentangle(acc)             Horner telescoping + bit-field
            (at the k == nk-1 flush)         split, incl. the dualword path

so entangle -> GEMM -> extract moves ``M*B*K + K*N`` words in and ``M*B*N``
out with zero intermediate HBM round-trips, vs the three-pass path's extra
``2*M*B*K + 2*M*B*N`` codec traffic (see benchmarks/kernel_micro.py).

Tiling: grid (B/bb, N/bn, K/bk), K innermost, with the small M stream axis
FULLY resident per tile — block (M, bb, bk). This replaces the earlier
double-binding of the same input (self tile + cyclic-predecessor tile, two
DMAs of identical bytes): with all M streams in one block the predecessor
row is a register roll, the operand is bound once, and the epilogue has
every stream's accumulator in VMEM to disentangle against.

``fuse_epilogue`` is a four-state switch selecting which codec halves run:

  ==============  =================  ===================
  fuse_epilogue   entangle prologue  extract at flush
  ==============  =================  ===================
  ``True``        yes                yes  (standalone fused GEMM)
  ``False``       yes                no   (raw entangled accumulators out)
  ``'chain'``     no                 no   (input ALREADY entangled)
  ``'chain_final'`` no               yes  (chain tail: extract only)
  ==============  =================  ===================

The chain modes exploit linearity of the codec over streams:
``(E c) @ g = E (c @ g)``, so feeding one call's entangled accumulators
straight into the next call's plain per-stream GEMM (no re-entangle, no
extract between) keeps the whole chain in the entangled domain — one
entangle, N GEMMs, one extract, and a fail-stopped stream's garbage stays
confined to its own stream until the final extraction statically skips it
(``failed=r``, same shifts/adds as the clean path).

``packed=True`` reads ``g`` with 4 int8 lanes per int32 word (packed along
K by :func:`repro.kernels.codec.pack_int8`): the weight block shrinks to
(bk/4, bn) in HBM/VMEM and is sign-extend-unpacked in registers before the
MXU dot — the q8 copies cost their true bytes end to end.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.plan import EntanglePlan
from repro.kernels.codec import (PACK_LANES, disentangle_block,
                                 entangle_block, unpack_int8)

# fuse_epilogue values whose prologue entangles / whose flush extracts
ENTANGLE_MODES = (False, True)
EXTRACT_MODES = (True, "chain_final")
CHAIN_MODES = ("chain", "chain_final")


def _emm_kernel(
    c_ref, g_ref, out_ref, acc_ref, *,
    plan: EntanglePlan, nk: int, fuse_epilogue, r: int, packed: bool,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c = c_ref[...]  # [M, bb, bk]
    eps = entangle_block(c, plan.l) if fuse_epilogue in ENTANGLE_MODES else c
    g = g_ref[...]
    if packed:  # [bk/4, bn] words -> [bk, bn] sign-extended lanes
        g = unpack_int8(g, axis=0)
    acc_ref[...] += jnp.stack(  # static unroll over streams; M is 3..8
        [jnp.dot(eps[m], g, preferred_element_type=jnp.int32)
         for m in range(plan.M)],
        axis=0,
    )

    @pl.when(k == nk - 1)
    def _flush():
        acc = acc_ref[...]
        if fuse_epilogue in EXTRACT_MODES:
            out_ref[...] = disentangle_block(acc, plan, r)
        else:
            out_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("plan", "fuse_epilogue", "failed", "bb", "bn", "bk",
                     "packed", "interpret"),
)
def entangled_matmul_pallas(
    c: jax.Array,
    g: jax.Array,
    *,
    plan: EntanglePlan,
    fuse_epilogue=False,
    failed: int = 0,
    bb: int = 128,
    bn: int = 128,
    bk: int = 128,
    packed: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Fused entangle[-GEMM-extract] for c:[M, B, K] int32, g:[K, N] int32.

    Returns entangled products delta[m] = (E c)[m] @ g when
    ``fuse_epilogue=False``, or the recovered true products d[m] = c[m] @ g
    when ``fuse_epilogue=True`` (extraction never reads stream ``failed``).
    ``'chain'`` / ``'chain_final'`` skip the entangle prologue (c must
    already be entangled) and keep / extract the entangled accumulators —
    see module docstring. With ``packed=True``, ``g`` is [K/4, N] packed
    int8 lanes. B, K, N must be multiples of bb, bk, bn (ops.py pads).
    """
    M, B, K = c.shape
    Kg, N = g.shape
    assert K == (Kg * PACK_LANES if packed else Kg), (K, Kg, packed)
    assert M == plan.M, (M, plan.M)
    grid = (B // bb, N // bn, K // bk)
    bkg = bk // PACK_LANES if packed else bk
    return pl.pallas_call(
        functools.partial(
            _emm_kernel, plan=plan, nk=grid[2],
            fuse_epilogue=fuse_epilogue, r=failed % M, packed=packed,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((M, bb, bk), lambda b, n, k: (0, b, k)),
            pl.BlockSpec((bkg, bn), lambda b, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((M, bb, bn), lambda b, n, k: (0, b, n)),
        out_shape=jax.ShapeDtypeStruct((M, B, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((M, bb, bn), jnp.int32)],
        interpret=interpret,
    )(c, g)
