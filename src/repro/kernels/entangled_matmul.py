"""Pallas TPU kernel: integer GEMM with entanglement fused into the load.

The paper notes entanglement can be applied "as data within each input stream
is being read" (stream-processor property). Here that becomes: the kernel
reads the stream-m and stream-(m-1) activation tiles from VMEM, forms
``eps_m = (c_{m-1} << l) + c_m`` in registers, and feeds the MXU directly —
the entangled operand never round-trips to HBM, so protection costs one
VPU shift-add per loaded tile on top of the unprotected GEMM.

Tiling: grid (M, B/bb, N/bn, K/bk), K innermost with a VMEM int32
accumulator; bb/bn/bk default to MXU-aligned 128 multiples. The same input
array is bound twice with two index maps (self tile and cyclic-predecessor
tile) — the TPU-idiomatic replacement for the paper's in-place AVX2 pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _emm_kernel(c_self_ref, c_prev_ref, g_ref, out_ref, acc_ref, *, l: int, nk: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    eps = jnp.left_shift(c_prev_ref[0], l) + c_self_ref[0]  # [bb, bk]
    acc_ref[...] += jnp.dot(
        eps, g_ref[...], preferred_element_type=jnp.int32
    )

    @pl.when(k == nk - 1)
    def _flush():
        out_ref[0, ...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("l", "bb", "bn", "bk", "interpret")
)
def entangled_matmul_pallas(
    c: jax.Array,
    g: jax.Array,
    *,
    l: int,
    bb: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """delta[m] = (E c)[m] @ g for c:[M, B, K] int32, g:[K, N] int32.

    B, K, N must be multiples of bb, bk, bn (ops.py pads/unpads).
    """
    M, B, K = c.shape
    K2, N = g.shape
    assert K == K2, (K, K2)
    grid = (M, B // bb, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_emm_kernel, l=l, nk=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bb, bk), lambda m, b, n, k: (m, b, k)),
            pl.BlockSpec((1, bb, bk), lambda m, b, n, k, _M=M: ((m - 1) % _M, b, k)),
            pl.BlockSpec((bk, bn), lambda m, b, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((1, bb, bn), lambda m, b, n, k: (m, b, n)),
        out_shape=jax.ShapeDtypeStruct((M, B, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bb, bn), jnp.int32)],
        interpret=interpret,
    )(c, c, g)
