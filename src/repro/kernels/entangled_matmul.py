"""Pallas TPU kernel: integer GEMM with the FULL entanglement codec fused.

The paper's throughput claim (1.8-2.8% overhead, Fig. 2) rests on the codec
never being a separate memory sweep: entanglement is applied "as data within
each input stream is being read" and extraction as results are written. This
kernel honors both halves in one ``pallas_call``:

  prologue  eps = (roll(c, 1) << l) + c      entangle-on-load, in registers
  body      acc[m] += eps[m] @ g             MXU, int32 accumulate in VMEM
  epilogue  d = disentangle(acc)             Horner telescoping + bit-field
            (at the k == nk-1 flush)         split, incl. the dualword path

so entangle -> GEMM -> extract moves ``M*B*K + K*N`` words in and ``M*B*N``
out with zero intermediate HBM round-trips, vs the three-pass path's extra
``2*M*B*K + 2*M*B*N`` codec traffic (see benchmarks/kernel_micro.py).

Tiling: grid (B/bb, N/bn, K/bk), K innermost, with the small M stream axis
FULLY resident per tile — block (M, bb, bk). This replaces the earlier
double-binding of the same input (self tile + cyclic-predecessor tile, two
DMAs of identical bytes): with all M streams in one block the predecessor
row is a register roll, the operand is bound once, and the epilogue has
every stream's accumulator in VMEM to disentangle against.

``fuse_epilogue=False`` writes the raw entangled accumulators (the serving
engine uses this when it must inject / inspect entangled outputs);
``failed=r`` statically excludes stream r's accumulator from extraction —
the fail-stop recovery path costs the same shifts/adds as the clean path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.plan import EntanglePlan
from repro.kernels.codec import disentangle_block, entangle_block


def _emm_kernel(
    c_ref, g_ref, out_ref, acc_ref, *,
    plan: EntanglePlan, nk: int, fuse_epilogue: bool, r: int,
):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    eps = entangle_block(c_ref[...], plan.l)  # [M, bb, bk], registers
    g = g_ref[...]
    acc_ref[...] += jnp.stack(  # static unroll over streams; M is 3..8
        [jnp.dot(eps[m], g, preferred_element_type=jnp.int32)
         for m in range(plan.M)],
        axis=0,
    )

    @pl.when(k == nk - 1)
    def _flush():
        acc = acc_ref[...]
        if fuse_epilogue:
            out_ref[...] = disentangle_block(acc, plan, r)
        else:
            out_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("plan", "fuse_epilogue", "failed", "bb", "bn", "bk",
                     "interpret"),
)
def entangled_matmul_pallas(
    c: jax.Array,
    g: jax.Array,
    *,
    plan: EntanglePlan,
    fuse_epilogue: bool = False,
    failed: int = 0,
    bb: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Fused entangle[-GEMM-extract] for c:[M, B, K] int32, g:[K, N] int32.

    Returns entangled products delta[m] = (E c)[m] @ g when
    ``fuse_epilogue=False``, or the recovered true products d[m] = c[m] @ g
    when ``fuse_epilogue=True`` (extraction never reads stream ``failed``).
    B, K, N must be multiples of bb, bk, bn (ops.py pads/unpads).
    """
    M, B, K = c.shape
    K2, N = g.shape
    assert K == K2, (K, K2)
    assert M == plan.M, (M, plan.M)
    grid = (B // bb, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(
            _emm_kernel, plan=plan, nk=grid[2],
            fuse_epilogue=fuse_epilogue, r=failed % M,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((M, bb, bk), lambda b, n, k: (0, b, k)),
            pl.BlockSpec((bk, bn), lambda b, n, k: (k, n)),
        ],
        out_specs=pl.BlockSpec((M, bb, bn), lambda b, n, k: (0, b, n)),
        out_shape=jax.ShapeDtypeStruct((M, B, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((M, bb, bn), jnp.int32)],
        interpret=interpret,
    )(c, g)
