"""Pure-jnp oracles for every Pallas kernel (allclose targets for tests).

These are the semantics; the kernels are the schedules. Each function is
shape-polymorphic and unpadded — ops.py aligns padding so kernel and oracle
can be compared elementwise (exact integer equality, not approximate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def entangle_ref(c: jax.Array, l: int) -> jax.Array:
    """eps_m = (c_{(m-1) mod M} << l) + c_m over axis 0."""
    c = c.astype(jnp.int32)
    return jnp.left_shift(jnp.roll(c, 1, axis=0), l) + c


def disentangle_ref(delta: jax.Array, plan, r: int = 0) -> jax.Array:
    """Delegates to the core reference implementation (already oracle-grade,
    itself validated against the numpy int64 oracle)."""
    from repro.core.entangle import disentangle

    return disentangle(delta.astype(jnp.int32), plan, failed=r)


def entangled_matmul_ref(c: jax.Array, g: jax.Array, l: int) -> jax.Array:
    """delta[m] = ((c_{m-1} << l) + c_m) @ g, int32 ring arithmetic."""
    eps = entangle_ref(c, l)
    return jnp.einsum(
        "mbk,kn->mbn", eps, g.astype(jnp.int32)
    ).astype(jnp.int32)


def entangled_matmul_fused_ref(c: jax.Array, g: jax.Array, plan,
                               r: int = 0) -> jax.Array:
    """Oracle for the fused epilogue: disentangled entangled products."""
    from repro.core.entangle import disentangle

    return disentangle(entangled_matmul_ref(c, g, plan.l), plan, failed=r)


def entangled_matmul_grouped_ref(c: jax.Array, g: jax.Array,
                                 l: int) -> jax.Array:
    """Grouped/per-expert variant: delta[m, e] = (E c)[m, e] @ g[e] for
    c [M, E, Cg, K], g [E, K, N] — entanglement spans the M axis only."""
    eps = entangle_ref(c, l)
    return jnp.einsum(
        "meck,ekn->mecn", eps, g.astype(jnp.int32)
    ).astype(jnp.int32)


def entangled_matmul_grouped_fused_ref(c: jax.Array, g: jax.Array, plan,
                                       r: int = 0) -> jax.Array:
    """Oracle for the fused grouped epilogue: per-expert disentangled
    products (each expert's GEMM is linear, so one disentangle over the
    stream axis recovers every expert at once)."""
    from repro.core.entangle import disentangle

    return disentangle(entangled_matmul_grouped_ref(c, g, plan.l), plan,
                       failed=r)


def entangled_conv1d_ref(x: jax.Array, w: jax.Array, l: int) -> jax.Array:
    """delta[m] = conv1d_causal(E x)[m] for x [M, B, D, T], w [D, K_f]."""
    eps = entangle_ref(x, l)
    M = x.shape[0]
    return jnp.stack([conv1d_causal_ref(eps[m], w) for m in range(M)], 0)


def entangled_conv1d_fused_ref(x: jax.Array, w: jax.Array, plan,
                               r: int = 0) -> jax.Array:
    """Oracle for the fused conv epilogue: true per-stream conv outputs."""
    from repro.core.entangle import disentangle

    return disentangle(entangled_conv1d_ref(x, w, plan.l), plan, failed=r)


def conv1d_causal_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """out[b,d,t] = sum_j w[d,j] * x[b,d,t-K_f+1+j] with zero left-pad."""
    B, D, T = x.shape
    _, kf = w.shape
    xp = jnp.pad(x.astype(jnp.int32), ((0, 0), (0, 0), (kf - 1, 0)))
    out = jnp.zeros((B, D, T), jnp.int32)
    for j in range(kf):
        out = out + w[None, :, j : j + 1].astype(jnp.int32) * xp[:, :, j : j + T]
    return out


def checksum_ref(c: jax.Array) -> jax.Array:
    return jnp.sum(c.astype(jnp.int32), axis=0, keepdims=True)
