"""Pallas TPU kernel: depthwise causal integer conv1d.

Convolution is the paper's experimental LSB op (Fig. 2) and also the conv
frontend of the assigned SSM/hybrid/audio architectures (Mamba conv1d,
Whisper/RecurrentGemma frontends use K_f in {3, 4}). This kernel covers the
short-filter depthwise case used inside models; long-kernel stream
convolution (paper Fig. 2, K up to 4500) goes through XLA's conv in
``benchmarks/`` where im2col/FFT strategies win.

Causality halo: each output tile of length ``bt`` needs ``K_f - 1`` trailing
inputs of the previous tile. Pallas blocks are uniform, so the input is bound
twice — current tile and predecessor tile — and the first tile's halo is
masked to zero (causal left padding). DMA cost of the second binding: each
grid step fetches a full extra (bd, bt) predecessor tile even though only
its trailing K_f - 1 columns are read, i.e. ~2x input traffic — only the
K_f-1 columns are *useful* (<1% at bt=512, K_f<=4), the rest is the price
of uniform blocks. Accepted for now because conv input bytes are a small
share of a model step's total traffic; the fix if it ever shows up on a
profile is carrying the previous tile's tail across grid steps in a VMEM
scratch instead of re-binding. (The GEMM kernel's former self/predecessor
double-binding is gone entirely: entangled_matmul.py now holds all M
streams in one block and rolls in registers.)

Works on entangled streams unchanged: depthwise conv is sesquilinear in the
stream, so ``conv(E c) = E conv(c)`` per the paper's Sec. III argument.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv1d_kernel(x_cur_ref, x_prev_ref, w_ref, out_ref, *, kf: int):
    t = pl.program_id(2)
    halo = x_prev_ref[0, :, -(kf - 1):]  # [bd, kf-1]
    halo = jnp.where(t == 0, jnp.zeros_like(halo), halo)  # causal zero pad
    window = jnp.concatenate([halo, x_cur_ref[0]], axis=-1)  # [bd, bt+kf-1]
    bt = out_ref.shape[-1]
    acc = jnp.zeros(out_ref.shape[1:], jnp.int32)
    for j in range(kf):  # static unroll over taps
        acc += w_ref[:, j : j + 1] * window[:, j : j + bt]
    out_ref[0, ...] = acc


@functools.partial(
    jax.jit, static_argnames=("bd", "bt", "interpret")
)
def conv1d_causal_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    bd: int = 128,
    bt: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Depthwise causal conv: x [B, D, T] int32, w [D, K_f] int32 ->
    out[b,d,t] = sum_j w[d,j] * x[b,d,t-K_f+1+j]. D % bd == 0, T % bt == 0,
    2 <= K_f <= bt (ops.py pads/unpads; K_f=1 is promoted there with a
    zero leading tap — the halo slice ``-(kf-1):`` needs kf >= 2)."""
    B, D, T = x.shape
    D2, kf = w.shape
    assert D == D2 and 2 <= kf <= bt
    grid = (B, D // bd, T // bt)
    return pl.pallas_call(
        functools.partial(_conv1d_kernel, kf=kf),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bd, bt), lambda b, d, t: (b, d, t)),
            # predecessor tile (halo); clamped at t=0 and masked in-kernel
            pl.BlockSpec(
                (1, bd, bt), lambda b, d, t: (b, d, jnp.maximum(t - 1, 0))
            ),
            pl.BlockSpec((bd, kf), lambda b, d, t: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, bd, bt), lambda b, d, t: (b, d, t)),
        out_shape=jax.ShapeDtypeStruct((B, D, T), jnp.int32),
        interpret=interpret,
    )(x, x, w)
