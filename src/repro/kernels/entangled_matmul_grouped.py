"""Pallas TPU kernel: grouped (per-expert) integer GEMM with the fused
entanglement codec — the MoE counterpart of :mod:`entangled_matmul`.

A Mixture-of-Experts layer runs E independent GEMMs per call, one per
expert, each over that expert's capacity-bounded row bucket:

    out[m, e] = c[m, e] @ g[e]        c: [M, E, Cg, K], g: [E, K, N]

Ragged token->expert assignments are padded to the uniform capacity Cg by
the dispatcher (exactly how capacity-bounded MoE already materializes its
expert buffers), so the kernel sees a *uniformly grouped* batch: the grid
simply gains a leading expert axis and every expert's tile reuses the
fused schedule of :mod:`entangled_matmul` verbatim:

  prologue  eps = (roll(c, 1) << l) + c      entangle-on-load, in registers
  body      acc[m] += eps[m, e] @ g[e]       MXU, int32 accumulate in VMEM
  epilogue  d = disentangle(acc)             at the k == nk-1 flush

Entanglement spans the M stream axis only — each expert's GEMM is linear,
so the codec commutes with it per expert and a fail-stopped stream's
outputs roll forward from the other M-1 accumulators inside the kernel
(``failed=r``), independently and identically for every expert. Zero pad
rows entangle to zeros and cannot perturb any live stream.

Tiling: grid (E, Cg/bb, N/bn, K/bk), K innermost; the expert axis is
blocked at 1 (each program owns one expert's (bb, bk)x(bk, bn) tile), the
small M stream axis is fully resident per block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.plan import EntanglePlan
from repro.kernels.codec import (PACK_LANES, disentangle_block,
                                 entangle_block, unpack_int8)


def _emmg_kernel(
    c_ref, g_ref, out_ref, acc_ref, *,
    plan: EntanglePlan, nk: int, fuse_epilogue: bool, r: int, packed: bool,
):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    eps = entangle_block(c_ref[:, 0], plan.l)  # [M, bb, bk], registers
    g = g_ref[0]  # [bk, bn] — this program's expert slice
    if packed:  # [bk/4, bn] words -> [bk, bn] sign-extended lanes
        g = unpack_int8(g, axis=0)
    acc_ref[...] += jnp.stack(  # static unroll over streams; M is 3..8
        [jnp.dot(eps[m], g, preferred_element_type=jnp.int32)
         for m in range(plan.M)],
        axis=0,
    )

    @pl.when(k == nk - 1)
    def _flush():
        acc = acc_ref[...]
        if fuse_epilogue:
            out_ref[...] = disentangle_block(acc, plan, r)[:, None]
        else:
            out_ref[...] = acc[:, None]


@functools.partial(
    jax.jit,
    static_argnames=("plan", "fuse_epilogue", "failed", "bb", "bn", "bk",
                     "packed", "interpret"),
)
def entangled_matmul_grouped_pallas(
    c: jax.Array,
    g: jax.Array,
    *,
    plan: EntanglePlan,
    fuse_epilogue: bool = False,
    failed: int = 0,
    bb: int = 128,
    bn: int = 128,
    bk: int = 128,
    packed: bool = False,
    interpret: bool = False,
) -> jax.Array:
    """Fused grouped entangle[-GEMM-extract]: c [M, E, Cg, K], g [E, K, N].

    Returns entangled per-expert products when ``fuse_epilogue=False`` or
    the recovered true products when ``True`` (extraction never reads
    stream ``failed``). With ``packed=True``, ``g`` is [E, K/4, N] packed
    int8 lanes (4 per int32 word along K), sign-extend-unpacked in VMEM
    registers before the MXU dot. Cg, K, N must be multiples of bb, bk, bn
    (ops.py pads/unpads); the expert axis E is never padded — the grid
    walks it.
    """
    M, E, Cg, K = c.shape
    E2, Kg, N = g.shape
    assert E == E2, (E, E2)
    assert K == (Kg * PACK_LANES if packed else Kg), (K, Kg, packed)
    assert M == plan.M, (M, plan.M)
    grid = (E, Cg // bb, N // bn, K // bk)
    bkg = bk // PACK_LANES if packed else bk
    return pl.pallas_call(
        functools.partial(
            _emmg_kernel, plan=plan, nk=grid[3],
            fuse_epilogue=fuse_epilogue, r=failed % M, packed=packed,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((M, 1, bb, bk), lambda e, b, n, k: (0, e, b, k)),
            pl.BlockSpec((1, bkg, bn), lambda e, b, n, k: (e, k, n)),
        ],
        out_specs=pl.BlockSpec((M, 1, bb, bn), lambda e, b, n, k: (0, e, b, n)),
        out_shape=jax.ShapeDtypeStruct((M, E, Cg, N), jnp.int32),
        scratch_shapes=[pltpu.VMEM((M, bb, bn), jnp.int32)],
        interpret=interpret,
    )(c, g)
