"""Block-size autotuner for the Pallas kernel layer.

Every kernel in this package is parameterized by block sizes (``bb/bn/bk``
for the fused GEMM, ``bd/bt`` for conv1d, ``block_n`` for the elementwise
codec passes). The right choice depends on shape, backend and whether the
codec epilogue is fused; hard-coding 128-multiples leaves throughput on the
table for the small/ragged shapes the serving path sees. This module sweeps
a candidate set once per (op, shape-signature, backend, flags) key and
caches the winner:

  * in-process: a plain dict, hit on every later call in the process;
  * on disk: a JSON file (``REPRO_AUTOTUNE_CACHE`` env var, default
    ``~/.cache/repro/autotune.json``) so tuned blocks survive restarts and
    can be shipped with a deployment;
  * shipped: pre-tuned seed caches under ``repro/kernels/pretuned/``
    (one JSON per backend generation, e.g. ``interpret_cpu.json``), loaded
    below the user cache file — a cold process whose shapes are covered
    never sweeps at all. Keys embed the backend tag, so loading every
    shipped file is safe; user-tuned winners always take precedence.

Cache file format — one flat JSON object::

    { "<op>|<shape-sig>|<backend>|<flags>": {"bb": 128, "bn": 256, ...},
      "_meta": {"version": 1} }

Keys are produced by :func:`cache_key`; values are exactly the block-size
kwargs the kernel wrapper passes through. Delete the file (or single keys)
to force a re-sweep. ``ops.py`` consults this module whenever a wrapper is
called with ``blocks="auto"``.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
import time
import warnings
from typing import Any, Callable, Iterable, Optional

import jax

__all__ = [
    "AutotuneCache",
    "cache_key",
    "candidates_for",
    "get_cache",
    "reset_cache",
    "stats",
    "tune",
]

_VERSION = 1

# shipped pre-tuned seed caches (per backend generation), lowest precedence
PRETUNED_DIR = pathlib.Path(__file__).resolve().parent / "pretuned"


def _pow2_leq(n: int, cap: int) -> int:
    """Largest power of two <= cap that is >= min(n, 8) — block floor 8."""
    p = 8
    while p * 2 <= min(n if n >= 8 else 8, cap):
        p *= 2
    return p


def candidates_for(op: str, **dims: int) -> list[dict[str, int]]:
    """Candidate block-size sets for ``op`` given problem dims.

    Candidates never exceed the next power of two of the corresponding dim
    (larger blocks only add padding) and always include the MXU/VPU-aligned
    128 defaults when the problem is big enough to use them.
    """
    def sizes(n: int, lo: int = 8, hi: int = 256) -> list[int]:
        top = _pow2_leq(2 * max(n, 1), hi)
        out, p = [], lo
        while p <= top:
            out.append(p)
            p *= 2
        return out or [lo]

    if op in ("entangled_matmul", "entangled_matmul_grouped"):
        B, N, K = dims["B"], dims["N"], dims["K"]
        return [
            {"bb": bb, "bn": bn, "bk": bk}
            for bb in sizes(B, 16, 128)
            for bn in sizes(N, 32, 256)
            for bk in sizes(K, 32, 256)
        ]
    if op in ("entangled_conv1d", "conv1d"):
        D, T = dims["D"], dims["T"]
        return [
            {"bd": bd, "bt": bt}
            for bd in sizes(D, 16, 128)
            for bt in sizes(T, 64, 512)
        ]
    if op in ("entangle", "disentangle", "checksum"):
        N = dims["N"]
        return [{"block_n": bn} for bn in sizes(N, 128, 4096)]
    raise KeyError(f"no candidate table for op {op!r}")


def cache_key(op: str, shape_sig: tuple, backend: str,
              flags: tuple = ()) -> str:
    sig = "x".join(str(s) for s in shape_sig)
    fl = ",".join(str(f) for f in flags)
    return f"{op}|{sig}|{backend}|{fl}"


class AutotuneCache:
    """Two-level (in-process dict + JSON file) winner cache with counters."""

    def __init__(self, path: Optional[str] = None):
        self.path = pathlib.Path(path).expanduser() if path else None
        self._mem: dict[str, dict[str, int]] = {}
        self._shipped: dict[str, dict[str, int]] = {}
        self._loaded = False
        self.hits = 0
        self.sweeps = 0

    @staticmethod
    def _parse_cache_json(text: str, origin: str) -> dict[str, dict]:
        """Parse one cache file defensively.

        A corrupted or partially-written cache (interrupted process, disk
        full, hand edit) must NEVER crash startup — the cache is an
        optimization, so malformed content degrades to "re-sweep / fall
        back to the pretuned seed" with a warning. Malformed entries are
        skipped individually: one bad key cannot poison the valid winners
        next to it.
        """
        try:
            data = json.loads(text)
        except ValueError as e:
            warnings.warn(
                f"autotune cache {origin} is not valid JSON ({e}); "
                f"ignoring it (winners fall back to the pretuned seed "
                f"cache or a fresh sweep)", RuntimeWarning, stacklevel=3)
            return {}
        if not isinstance(data, dict):
            warnings.warn(
                f"autotune cache {origin} must be a JSON object, got "
                f"{type(data).__name__}; ignoring it",
                RuntimeWarning, stacklevel=3)
            return {}
        out: dict[str, dict] = {}
        bad = []
        for k, v in data.items():
            if k == "_meta":
                continue
            try:
                out[k] = {kk: int(vv) for kk, vv in v.items()}
            except (AttributeError, TypeError, ValueError):
                bad.append(k)
        if bad:
            warnings.warn(
                f"autotune cache {origin}: skipped {len(bad)} malformed "
                f"entries (e.g. {bad[0]!r}); remaining winners kept",
                RuntimeWarning, stacklevel=3)
        return out

    @staticmethod
    def _known_namespace(key: str, *, ops_too: bool = False) -> bool:
        """True when the key names a backend registered in this process —
        and, with ``ops_too`` (the SHIPPED pretuned files), an op this
        build tunes.

        Keys from a pre-v2 cache (backend tag ``interpret``/``cpu``) or
        from a port that is not registered in THIS process can never match
        a lookup here — loading them would only inflate stats and mask the
        fact that those shapes will re-sweep. USER caches keep free-form
        op fields (library callers may tune private ops through this
        cache); the op check applies only to the files we ship, where an
        unknown op means a stale generation left behind by a rename."""
        from repro.kernels import ops  # deferred: ops imports this module

        parts = key.split("|")
        if len(parts) < 3 or parts[2] not in ops.backend_names():
            return False
        if not ops_too:
            return True
        known_ops = set(ops.REQUIRED_OPS) | {
            "entangle", "disentangle", "checksum", "conv1d"}
        return parts[0] in known_ops

    def _load_file(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        if self.path and self.path.exists():
            try:
                text = self.path.read_text()
            except OSError as e:
                warnings.warn(f"autotune cache {self.path} unreadable "
                              f"({e}); ignoring it", RuntimeWarning)
                text = "{}"
            stale = 0
            for k, v in self._parse_cache_json(text, str(self.path)).items():
                if self._known_namespace(k):
                    self._mem.setdefault(k, v)
                else:
                    stale += 1
            if stale:
                warnings.warn(
                    f"autotune cache {self.path}: ignored {stale} entries "
                    f"from op/backend namespaces not registered in this "
                    f"process (pre-v2 cache, stale generation or unloaded "
                    f"port); those shapes will re-tune", RuntimeWarning)
        # shipped seed caches: consulted AFTER in-process and file winners
        # (kept in their own dict so `put` never re-persists them)
        if PRETUNED_DIR.is_dir():
            for f in sorted(PRETUNED_DIR.glob("*.json")):
                try:
                    text = f.read_text()
                except OSError:
                    continue
                stale = 0
                for k, v in self._parse_cache_json(
                        text, f"pretuned/{f.name}").items():
                    if self._known_namespace(k, ops_too=True):
                        self._shipped.setdefault(k, v)
                    else:
                        stale += 1
                if stale:
                    warnings.warn(
                        f"autotune pretuned/{f.name}: dropped {stale} stale "
                        f"entries (op or backend namespace unknown to this "
                        f"build); covered shapes still cold-hit",
                        RuntimeWarning)

    def get(self, key: str) -> Optional[dict[str, int]]:
        self._load_file()
        hit = self._mem.get(key)
        if hit is None:
            hit = self._shipped.get(key)
        if hit is not None:
            self.hits += 1
        return hit

    def put(self, key: str, blocks: dict[str, int]) -> None:
        self._load_file()
        self._mem[key] = dict(blocks)
        if self.path:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            # re-read + merge before writing: concurrent processes sharing
            # the file must not clobber winners persisted after our load
            # (ours win on key conflicts — they are fresher)
            on_disk: dict = {}
            if self.path.exists():
                try:
                    on_disk = self._parse_cache_json(self.path.read_text(),
                                                     str(self.path))
                except OSError:
                    on_disk = {}
            payload = {"_meta": {"version": _VERSION}, **on_disk, **self._mem}
            # atomic replace: concurrent processes never see a torn file
            fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                       prefix=".autotune-")
            try:
                with os.fdopen(fd, "w") as f:
                    json.dump(payload, f, indent=1, sort_keys=True)
                os.replace(tmp, self.path)
            except OSError:
                try:
                    os.unlink(tmp)
                except OSError:
                    pass


_cache: Optional[AutotuneCache] = None


def get_cache() -> AutotuneCache:
    global _cache
    if _cache is None:
        path = os.environ.get(
            "REPRO_AUTOTUNE_CACHE",
            str(pathlib.Path.home() / ".cache" / "repro" / "autotune.json"),
        )
        _cache = AutotuneCache(path or None)
    return _cache


def reset_cache(path: Optional[str] = None) -> AutotuneCache:
    """Swap in a fresh cache (tests; or to point at a shipped cache file)."""
    global _cache
    _cache = AutotuneCache(path)
    return _cache


def stats() -> dict:
    """Cache counters for startup-warmup reporting (launch/serve --smoke):
    sweeps = shapes tuned this process, hits = cache hits (in-process,
    the JSON file, or a shipped pre-tuned seed cache), keys = distinct
    winners usable on THIS process's default kernel backend (shipped files
    carry every backend namespace; foreign-backend keys can never hit here
    and would inflate the coverage counter)."""
    from repro.kernels import ops  # deferred: ops imports this module

    c = get_cache()
    c._load_file()
    tag = ops.resolve_backend()
    usable = {k for k in c._shipped if k.split("|")[2] == tag}
    return {"hits": c.hits, "sweeps": c.sweeps,
            "keys": len(set(c._mem) | usable)}


def _time_once(thunk: Callable[[], Any]) -> float:
    t0 = time.perf_counter()
    jax.block_until_ready(thunk())
    return time.perf_counter() - t0


def tune(
    op: str,
    shape_sig: tuple,
    backend: str,
    bench: Callable[[dict[str, int]], Callable[[], Any]],
    *,
    candidates: Optional[Iterable[dict[str, int]]] = None,
    flags: tuple = (),
    repeats: int = 2,
    cache: Optional[AutotuneCache] = None,
) -> dict[str, int]:
    """Return the winning block sizes for ``op`` on ``shape_sig``.

    ``bench(blocks)`` must return a zero-arg thunk running the kernel with
    those blocks on representative inputs. Sweeps (compile + best-of-N
    timing per candidate) only on a cache miss; winners persist in-process
    and in the JSON file.
    """
    cache = cache or get_cache()
    key = cache_key(op, shape_sig, backend, flags)
    cached = cache.get(key)
    if cached is not None:
        return cached

    cands = (list(candidates) if candidates is not None
             else candidates_for(op, **_sig_dims(op, shape_sig)))

    cache.sweeps += 1
    best_t, best, last_exc = float("inf"), None, None
    for cand in cands:
        try:
            thunk = bench(cand)
            jax.block_until_ready(thunk())  # warmup / compile
            t = min(_time_once(thunk) for _ in range(repeats))
        except Exception as e:  # invalid candidate for this shape/backend
            last_exc = e
            continue
        if t < best_t:
            best_t, best = t, cand
    if best is None:
        raise RuntimeError(
            f"autotune: no candidate ran for {key} "
            f"({len(cands)} tried)"
        ) from last_exc
    cache.put(key, best)
    return best


def _sig_dims(op: str, shape_sig: tuple) -> dict[str, int]:
    """Map a shape signature to the named dims candidates_for expects."""
    if op == "entangled_matmul":
        M, B, K, N = shape_sig
        return {"B": B, "N": N, "K": K}
    if op == "entangled_matmul_grouped":
        # the expert axis never changes block choices (blocked at 1); the
        # per-expert row bucket Cg plays the batch role
        M, E, Cg, K, N = shape_sig
        return {"B": Cg, "N": N, "K": K}
    if op in ("entangled_conv1d",):
        M, B, D, T, kf = shape_sig
        return {"D": D, "T": T}
    if op == "conv1d":
        B, D, T, kf = shape_sig
        return {"D": D, "T": T}
    if op in ("entangle", "disentangle", "checksum"):
        return {"N": shape_sig[-1]}
    raise KeyError(op)
