"""Dispatch layer over the entangled kernels — pluggable at the bottom.

Every public wrapper here handles, uniformly:

  * arbitrary trailing shapes (flattened to the sample axis) and padding to
    block multiples (zero padding is exact for integer LSB ops);
  * **backend dispatch through a registry** — each backend provides the
    three entangled LSB ops (``entangled_matmul``, ``entangled_conv1d``,
    ``entangled_matmul_grouped``) behind one calling convention; shipped
    backends are

      - ``pallas_tpu``     the compiled Pallas TPU kernels,
      - ``interpret_cpu``  the same kernels under ``interpret=True`` (the
                           task-mandated CPU validation mode; default off
                           TPU),
      - ``reference``      the pure-jnp oracles from :mod:`ref` (XLA
                           compiles them; no Pallas at all),

    and :func:`register_backend` accepts ports (see *Porting to
    Triton/CUDA* below). Selection order per call: explicit ``backend=``
    kwarg > legacy ``interpret=`` flag > process default
    (:func:`set_default_backend`, else platform: ``pallas_tpu`` on TPU,
    ``interpret_cpu`` elsewhere);
  * block-size dispatch via the ``blocks`` argument:
      - ``None``: shape-aware defaults (power-of-two, capped at the
        MXU/VPU-aligned 128/512 tiles);
      - a dict: explicit override, merged over the defaults;
      - ``"auto"``: the :mod:`repro.kernels.autotune` subsystem — sweep
        once per (op, shape, backend, flags) key, then cache-hit. Keys are
        **backend-namespaced** (the registry name is the key's backend
        field), so a registered port autotunes into its own namespace and
        the shipped pre-tuned seed caches (``kernels/pretuned/<name>.json``)
        can never leak winners across backends;
  * codec fusion via ``fuse_epilogue`` on the LSB-op wrappers: ``True``
    returns extracted true outputs from ONE fused kernel call (entangle ->
    op -> extract, zero intermediate HBM round-trips); ``False`` returns
    entangled outputs for callers that inject failures / persist entangled
    state, to be recovered later with :func:`disentangle`.

Porting to Triton/CUDA
----------------------
A port registers an impls dict mapping the three op names to callables with
the padded-call convention (see :data:`REQUIRED_OPS` and the builtin
registrations at the bottom of this module)::

    ops.register_backend("triton_cuda", {
        "entangled_matmul": my_triton_emm,          # (c, g, *, plan,
        "entangled_conv1d": my_triton_conv,         #  fuse_epilogue,
        "entangled_matmul_grouped": my_triton_emmg, #  failed, blocks,
    }, interpret=False)                             #  packed)

Each callable receives block-multiple-padded int32 operands and the
resolved ``blocks`` dict and must reproduce the reference oracle
bit-exactly (``tests/test_fused_codec.py`` parametrizes over registered
backends' semantics; the codec is shifts/adds, so any backend that
accumulates in int32 matches). :func:`triton_cuda_stub` returns a
placeholder impls dict whose entries raise ``NotImplementedError`` with
these porting notes — register it to reserve the namespace before the
kernels exist. Pre-tuned block sizes ship per backend as
``kernels/pretuned/<backend>.json``.

The per-kernel legacy block kwargs (``bb=/bn=/bk=``, ``bd=/bt=``,
``block_n=``) remain accepted and act as defaults under ``blocks``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Optional, Union

import jax
import jax.numpy as jnp

from repro.core.plan import EntanglePlan
from repro.kernels import autotune as at
from repro.kernels import codec
from repro.kernels import ref
from repro.kernels.codec import PACK_LANES
from repro.kernels.checksum import checksum_pallas
from repro.kernels.conv1d import conv1d_causal_pallas
from repro.kernels.disentangle import disentangle_pallas
from repro.kernels.entangle import entangle_pallas
from repro.kernels.entangled_conv1d import entangled_conv1d_pallas
from repro.kernels.entangled_matmul import entangled_matmul_pallas
from repro.kernels.entangled_matmul_grouped import (
    entangled_matmul_grouped_pallas)

Blocks = Union[None, str, dict]

# the op surface every backend must implement (padded-call convention)
REQUIRED_OPS = ("entangled_matmul", "entangled_conv1d",
                "entangled_matmul_grouped")


@dataclasses.dataclass(frozen=True)
class KernelBackend:
    """One registered kernel backend.

    ``impls`` maps each :data:`REQUIRED_OPS` name to a callable taking the
    block-multiple-padded int32 operands plus ``plan`` / ``fuse_epilogue``
    / ``failed`` / ``blocks`` keywords. ``interpret`` is the Pallas
    interpret flag used for the standalone codec passes (entangle /
    disentangle / checksum) that backends do not override.
    """

    name: str
    impls: Mapping[str, Callable]
    interpret: bool = True
    description: str = ""


_BACKENDS: dict[str, KernelBackend] = {}
_DEFAULT: Optional[str] = None  # set_default_backend override


def register_backend(name: str, impls: Mapping[str, Callable], *,
                     interpret: bool = True,
                     description: str = "") -> KernelBackend:
    """Register (or replace) a kernel backend under ``name``.

    ``impls`` must cover every op in :data:`REQUIRED_OPS`. Autotune keys
    for the backend are namespaced by ``name`` — a port never shares (or
    clobbers) another backend's winners, and a pre-tuned seed cache
    shipped as ``kernels/pretuned/<name>.json`` is picked up automatically.
    """
    missing = [op for op in REQUIRED_OPS if op not in impls]
    if missing:
        raise ValueError(
            f"backend {name!r} is missing required ops {missing}; every "
            f"backend must provide {list(REQUIRED_OPS)}")
    b = KernelBackend(name=name, impls=dict(impls), interpret=interpret,
                      description=description)
    _BACKENDS[name] = b
    return b


def unregister_backend(name: str) -> None:
    """Remove a registered backend (and the default pin, if it was it)."""
    global _DEFAULT
    _BACKENDS.pop(name, None)
    if _DEFAULT == name:
        _DEFAULT = None


def backend_names() -> tuple:
    return tuple(sorted(_BACKENDS))


def get_backend(name: str) -> KernelBackend:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise KeyError(
            f"no kernel backend {name!r} registered; known: "
            f"{backend_names()}") from None


def set_default_backend(name: Optional[str]) -> None:
    """Pin the process-wide default backend (None restores the platform
    rule: ``pallas_tpu`` on TPU, ``interpret_cpu`` elsewhere)."""
    global _DEFAULT
    if name is not None:
        get_backend(name)  # validate
    _DEFAULT = name


def resolve_backend(backend: Optional[str] = None,
                    interpret=None) -> str:
    """Resolve a wrapper call's backend name.

    Precedence: explicit ``backend`` kwarg > legacy ``interpret`` flag
    (True -> ``interpret_cpu``, False -> ``pallas_tpu``) > process default
    > platform rule. The returned name is also the autotune/pretuned cache
    namespace for the call.
    """
    if backend is not None:
        get_backend(backend)
        return backend
    if interpret is True:
        return "interpret_cpu"
    if interpret is False:
        return "pallas_tpu"
    if _DEFAULT is not None:
        return _DEFAULT
    return "pallas_tpu" if jax.default_backend() == "tpu" else "interpret_cpu"


def _pad_to(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def _resolve_blocks(op: str, defaults: dict, blocks: Blocks, shape_sig: tuple,
                    backend: str, bench, flags: tuple = ()) -> dict:
    """Merge/auto-tune the block sizes for one wrapper call."""
    if blocks is None:
        return defaults
    if isinstance(blocks, dict):
        return {**defaults, **blocks}
    if blocks == "auto":
        return at.tune(op, shape_sig, backend, bench, flags=flags)
    raise ValueError(f"blocks must be None, a dict or 'auto', got {blocks!r}")


# --------------------------------------------------------------- codec ------

def _plan_flags(plan: EntanglePlan) -> tuple:
    """Autotune key component for the codec parameters: the Horner depth
    and temp mode change the epilogue cost, so winners must not be shared
    across plans that merely agree on M and shapes."""
    return (f"l{plan.l}", plan.temp)


def _codec_pass(op: str, kernel_call, x: jax.Array, block_n: int,
                blocks: Blocks, backend: str, flags: tuple = ()):
    """Shared flatten -> pad -> resolve/tune -> kernel path for the
    elementwise [M, N] codec sweeps. ``kernel_call(padded, bn, interp)``
    invokes the kernel; returns (out, valid_n, original_shape)."""
    shape = x.shape
    flat = x.reshape(shape[0], -1).astype(jnp.int32)
    interp = get_backend(backend).interpret

    def bench(bl):
        padded, _ = _pad_to(flat, 1, bl["block_n"])
        return lambda: kernel_call(padded, bl["block_n"], interp)

    bl = _resolve_blocks(op, {"block_n": block_n}, blocks,
                         (shape[0], flat.shape[1]), backend, bench,
                         flags=flags)
    padded, n = _pad_to(flat, 1, bl["block_n"])
    return kernel_call(padded, bl["block_n"], interp), n, shape


def entangle(c: jax.Array, plan: EntanglePlan, *, block_n: int = 1024,
             blocks: Blocks = None, interpret=None,
             backend: Optional[str] = None) -> jax.Array:
    """Entangle M streams of any trailing shape ([M, ...] int)."""
    out, n, shape = _codec_pass(
        "entangle",
        lambda p, bn, it: entangle_pallas(p, l=plan.l, block_n=bn,
                                          interpret=it),
        c, block_n, blocks, resolve_backend(backend, interpret),
        flags=_plan_flags(plan))
    return out[:, :n].reshape(shape)


def disentangle(delta: jax.Array, plan: EntanglePlan, *,
                failed: Optional[int] = None, block_n: int = 1024,
                blocks: Blocks = None, interpret=None,
                backend: Optional[str] = None) -> jax.Array:
    """Recover all M outputs from entangled outputs of any trailing shape."""
    r = 0 if failed is None else failed
    out, n, shape = _codec_pass(
        "disentangle",
        lambda p, bn, it: disentangle_pallas(p, plan=plan, r=r, block_n=bn,
                                             interpret=it),
        delta, block_n, blocks, resolve_backend(backend, interpret),
        flags=_plan_flags(plan))
    return out[:, :n].reshape(shape)


def checksum(c: jax.Array, *, block_n: int = 1024, blocks: Blocks = None,
             interpret=None, backend: Optional[str] = None) -> jax.Array:
    """Checksum stream r = sum_m c_m for [M, ...] inputs -> [...]."""
    out, n, shape = _codec_pass(
        "checksum",
        lambda p, bn, it: checksum_pallas(p, block_n=bn, interpret=it),
        c, block_n, blocks, resolve_backend(backend, interpret))
    return out[0, :n].reshape(shape[1:])


# ------------------------------------------------------------- LSB ops ------

# valid fuse_epilogue values for the dense GEMM; grouped/conv accept only
# the first two (chaining is a dense-site feature — see ft/protected.py)
_FUSE_MODES = (False, True, "chain", "chain_final")


def _check_fuse(fuse_epilogue, *, chain_ok: bool) -> None:
    valid = _FUSE_MODES if chain_ok else _FUSE_MODES[:2]
    if fuse_epilogue not in valid:
        raise ValueError(
            f"fuse_epilogue must be one of {valid}, got {fuse_epilogue!r}")


def entangled_matmul(c: jax.Array, g: jax.Array, plan: EntanglePlan, *,
                     fuse_epilogue=False,
                     failed: Optional[int] = None,
                     bb: int = 128, bn: int = 128, bk: int = 128,
                     packed: bool = False,
                     blocks: Blocks = None, interpret=None,
                     backend: Optional[str] = None) -> jax.Array:
    """Fused entangle+GEMM[+extract]: c [M, B, K], g [K, N] int.

    ``fuse_epilogue=False`` -> entangled products [M, B, N] (recover later
    via :func:`disentangle`). ``fuse_epilogue=True`` -> true products, the
    codec never leaving the kernel; ``failed`` statically excludes one
    stream's accumulator from the in-kernel extraction. The chain modes
    ``'chain'`` / ``'chain_final'`` skip the entangle prologue — ``c`` must
    already be entangled (e.g. a previous call's ``fuse_epilogue=False``
    output) — and return entangled / extracted products respectively, so
    consecutive linear GEMMs compose without leaving the entangled domain.
    ``packed=True`` declares ``g`` as [ceil(K/4), N] int8 lanes packed 4
    per int32 word along K (:func:`repro.kernels.codec.pack_int8`); the
    kernels sign-extend-unpack in registers, so the weight sweep costs its
    true int8 bytes.
    """
    _check_fuse(fuse_epilogue, chain_ok=True)
    M, B, K = c.shape
    N = g.shape[1]
    c32 = c.astype(jnp.int32)
    g32 = g.astype(jnp.int32)
    bname = resolve_backend(backend, interpret)
    impl = get_backend(bname).impls["entangled_matmul"]
    r = 0 if failed is None else failed

    def call(bl, cc, gg):
        cp, _ = _pad_to(cc, 1, bl["bb"])
        cp, _ = _pad_to(cp, 2, bl["bk"])
        # packed weights pad along K in words (bk/4 words == bk lanes)
        gp, _ = _pad_to(gg, 0, bl["bk"] // PACK_LANES if packed else bl["bk"])
        gp, _ = _pad_to(gp, 1, bl["bn"])
        return impl(cp, gp, plan=plan, fuse_epilogue=fuse_epilogue,
                    failed=r, blocks=bl, packed=packed)

    bl = _resolve_blocks(
        "entangled_matmul", {"bb": bb, "bn": bn, "bk": bk}, blocks,
        (M, B, K, N), bname, lambda b: (lambda: call(b, c32, g32)),
        flags=_matmul_flags(plan, fuse_epilogue, packed))
    out = call(bl, c32, g32)
    return out[:, :B, :N]


def entangled_matmul_grouped(c: jax.Array, g: jax.Array, plan: EntanglePlan,
                             *, fuse_epilogue: bool = False,
                             failed: Optional[int] = None,
                             bb: int = 128, bn: int = 128, bk: int = 128,
                             packed: bool = False,
                             blocks: Blocks = None, interpret=None,
                             backend: Optional[str] = None) -> jax.Array:
    """Grouped fused entangle+GEMM[+extract] — the MoE per-expert form:
    c [M, E, Cg, K], g [E, K, N] int -> [M, E, Cg, N].

    Expert e's rows multiply expert e's weights; the codec spans the M
    stream axis only, so recovery semantics are identical to
    :func:`entangled_matmul` applied per expert (one kernel call covers
    all E). Ragged per-expert row counts must be padded to the uniform
    ``Cg`` by the caller with zero rows (exact — this is the same
    capacity-padding a bounded MoE dispatcher already performs).
    ``packed=True`` declares ``g`` as [E, ceil(K/4), N] int8 lanes packed
    along K. Chain modes are dense-only (raises here).
    """
    _check_fuse(fuse_epilogue, chain_ok=False)
    M, E, Cg, K = c.shape
    N = g.shape[2]
    c32 = c.astype(jnp.int32)
    g32 = g.astype(jnp.int32)
    bname = resolve_backend(backend, interpret)
    impl = get_backend(bname).impls["entangled_matmul_grouped"]
    r = 0 if failed is None else failed

    def call(bl, cc, gg):
        cp, _ = _pad_to(cc, 2, bl["bb"])
        cp, _ = _pad_to(cp, 3, bl["bk"])
        gp, _ = _pad_to(gg, 1, bl["bk"] // PACK_LANES if packed else bl["bk"])
        gp, _ = _pad_to(gp, 2, bl["bn"])
        return impl(cp, gp, plan=plan, fuse_epilogue=fuse_epilogue,
                    failed=r, blocks=bl, packed=packed)

    bl = _resolve_blocks(
        "entangled_matmul_grouped", {"bb": bb, "bn": bn, "bk": bk}, blocks,
        (M, E, Cg, K, N), bname, lambda b: (lambda: call(b, c32, g32)),
        flags=_matmul_flags(plan, fuse_epilogue, packed))
    out = call(bl, c32, g32)
    return out[:, :, :Cg, :N]


def _matmul_flags(plan: EntanglePlan, fuse_epilogue,
                  packed: bool = False) -> tuple:
    """Autotune flags for the fused GEMMs — single source of truth for the
    wrapper's tune call and the startup warm's cache lookup. Every
    fuse/packed variant gets its own namespace: the epilogue and the
    unpack prologue both change the kernel's cost profile, so winners must
    never be shared across them."""
    flags = _plan_flags(plan)
    if fuse_epilogue is True:
        flags += ("fused",)
    elif fuse_epilogue == "chain":
        flags += ("chain",)
    elif fuse_epilogue == "chain_final":
        flags += ("chainf",)
    if packed:
        flags += ("packed",)
    return flags


def warm_entangled_matmul(M: int, B: int, K: int, N: int, plan: EntanglePlan,
                          *, fuse_epilogue=True, packed: bool = False,
                          interpret=None,
                          backend: Optional[str] = None) -> dict:
    """Eagerly autotune the fused GEMM for one (M, B, K, N) serving shape.

    The serving engine calls this at startup for every shape in its census:
    the sweep runs HERE, eagerly on real buffers, so that ``blocks="auto"``
    inside the engine's jitted decode step is a pure in-process cache hit
    (a sweep during tracing would time tracers, not kernels). ``failed`` is
    deliberately not part of the autotune key, so one warm covers healthy
    and every fail-stop-injected variant. Returns the winning block sizes.
    """
    c = jnp.zeros((M, B, K), jnp.int32)
    Kg = -(-K // PACK_LANES) if packed else K
    g = jnp.zeros((Kg, N), jnp.int32)
    entangled_matmul(c, g, plan, fuse_epilogue=fuse_epilogue, packed=packed,
                     blocks="auto", interpret=interpret, backend=backend)
    key = at.cache_key("entangled_matmul", (M, B, K, N),
                       resolve_backend(backend, interpret),
                       _matmul_flags(plan, fuse_epilogue, packed))
    return at.get_cache().get(key) or {}


def warm_entangled_matmul_grouped(M: int, E: int, Cg: int, K: int, N: int,
                                  plan: EntanglePlan, *,
                                  fuse_epilogue: bool = True,
                                  packed: bool = False, interpret=None,
                                  backend: Optional[str] = None) -> dict:
    """Grouped twin of :func:`warm_entangled_matmul` for the MoE
    per-expert shapes of the engine census."""
    c = jnp.zeros((M, E, Cg, K), jnp.int32)
    Kg = -(-K // PACK_LANES) if packed else K
    g = jnp.zeros((E, Kg, N), jnp.int32)
    entangled_matmul_grouped(c, g, plan, fuse_epilogue=fuse_epilogue,
                             packed=packed, blocks="auto",
                             interpret=interpret, backend=backend)
    key = at.cache_key("entangled_matmul_grouped", (M, E, Cg, K, N),
                       resolve_backend(backend, interpret),
                       _matmul_flags(plan, fuse_epilogue, packed))
    return at.get_cache().get(key) or {}


def entangled_conv1d(x: jax.Array, w: jax.Array, plan: EntanglePlan, *,
                     fuse_epilogue: bool = False,
                     failed: Optional[int] = None,
                     bd: int = 128, bt: int = 512,
                     packed: bool = False,
                     blocks: Blocks = None, interpret=None,
                     backend: Optional[str] = None) -> jax.Array:
    """Fused entangle+depthwise-causal-conv[+extract]: x [M, B, D, T],
    w [D, K_f] int. Same fusion semantics as :func:`entangled_matmul`;
    ``packed=True`` declares ``w`` as [ceil(D/4), K_f] int8 lanes packed
    along the depth axis. Chain modes are dense-only (raises here)."""
    _check_fuse(fuse_epilogue, chain_ok=False)
    M, B, D, T = x.shape
    kf = w.shape[1]
    x32 = x.astype(jnp.int32)
    w32 = w.astype(jnp.int32)
    if kf == 1:  # kernel needs a halo; a zero leading tap is exact
        w32 = jnp.pad(w32, ((0, 0), (1, 0)))  # (zero packed word == 4
        kf = 2                                #  zero lanes, still exact)
    bname = resolve_backend(backend, interpret)
    impl = get_backend(bname).impls["entangled_conv1d"]
    r = 0 if failed is None else failed

    def call(bl, xx, ww):
        xp, _ = _pad_to(xx, 2, bl["bd"])
        xp, _ = _pad_to(xp, 3, bl["bt"])
        wp, _ = _pad_to(ww, 0, bl["bd"] // PACK_LANES if packed else bl["bd"])
        return impl(xp, wp, plan=plan, fuse_epilogue=fuse_epilogue,
                    failed=r, blocks=bl, packed=packed)

    bl = _resolve_blocks(
        "entangled_conv1d", {"bd": bd, "bt": bt}, blocks,
        (M, B, D, T, kf), bname, lambda b: (lambda: call(b, x32, w32)),
        flags=_plan_flags(plan) + (("fused",) if fuse_epilogue else ())
        + (("packed",) if packed else ()))
    out = call(bl, x32, w32)
    return out[:, :, :D, :T]


def conv1d_causal(x: jax.Array, w: jax.Array, *, bd: int = 128, bt: int = 512,
                  blocks: Blocks = None, interpret=None,
                  backend: Optional[str] = None) -> jax.Array:
    """Depthwise causal conv1d (unentangled): x [B, D, T], w [D, K_f]."""
    B, D, T = x.shape
    x32 = x.astype(jnp.int32)
    w32 = w.astype(jnp.int32)
    if w32.shape[1] == 1:  # kernel's halo slice needs K_f >= 2; a zero
        w32 = jnp.pad(w32, ((0, 0), (1, 0)))  # leading tap is exact
    bname = resolve_backend(backend, interpret)
    interp = get_backend(bname).interpret

    def call(bl, xx, ww):
        xp, _ = _pad_to(xx, 1, bl["bd"])
        xp, _ = _pad_to(xp, 2, bl["bt"])
        wp, _ = _pad_to(ww, 0, bl["bd"])
        return conv1d_causal_pallas(
            xp, wp, bd=bl["bd"], bt=bl["bt"], interpret=interp)

    bl = _resolve_blocks(
        "conv1d", {"bd": bd, "bt": bt}, blocks,
        (B, D, T, w.shape[1]), bname, lambda b: (lambda: call(b, x32, w32)))
    out = call(bl, x32, w32)
    return out[:, :D, :T]


# --------------------------------------------------- builtin backends -------

def _pallas_impls(interpret: bool) -> dict:
    return {
        "entangled_matmul": lambda c, g, *, plan, fuse_epilogue, failed,
        blocks, packed=False: entangled_matmul_pallas(
            c, g, plan=plan, fuse_epilogue=fuse_epilogue, failed=failed,
            bb=blocks["bb"], bn=blocks["bn"], bk=blocks["bk"],
            packed=packed, interpret=interpret),
        "entangled_matmul_grouped": lambda c, g, *, plan, fuse_epilogue,
        failed, blocks, packed=False: entangled_matmul_grouped_pallas(
            c, g, plan=plan, fuse_epilogue=fuse_epilogue, failed=failed,
            bb=blocks["bb"], bn=blocks["bn"], bk=blocks["bk"],
            packed=packed, interpret=interpret),
        "entangled_conv1d": lambda x, w, *, plan, fuse_epilogue, failed,
        blocks, packed=False: entangled_conv1d_pallas(
            x, w, plan=plan, fuse_epilogue=fuse_epilogue, failed=failed,
            bd=blocks["bd"], bt=blocks["bt"], packed=packed,
            interpret=interpret),
    }


def _ref_impls() -> dict:
    """The jnp oracles as a backend: semantics without any Pallas schedule
    (XLA lowers them directly; ``blocks`` is accepted and ignored). Packed
    weights are unpacked up front — the oracle defines semantics, not a
    memory schedule — and the chain modes compose the oracle pieces: a
    plain per-stream GEMM on the already-entangled input (linearity:
    ``(E c) @ g = E (c @ g)``), extracting only in ``'chain_final'``."""
    def emm(c, g, *, plan, fuse_epilogue, failed, blocks, packed=False):
        if packed:
            g = codec.unpack_int8(g, axis=0)
        if fuse_epilogue in ("chain", "chain_final"):
            out = jnp.stack([jnp.dot(c[m], g,
                                     preferred_element_type=jnp.int32)
                             for m in range(plan.M)], axis=0)
            if fuse_epilogue == "chain_final":
                out = codec.disentangle_block(out, plan, failed)
            return out
        if fuse_epilogue:
            return ref.entangled_matmul_fused_ref(c, g, plan, r=failed)
        return ref.entangled_matmul_ref(c, g, plan.l)

    def emmg(c, g, *, plan, fuse_epilogue, failed, blocks, packed=False):
        if packed:
            g = codec.unpack_int8(g, axis=1)
        if fuse_epilogue:
            return ref.entangled_matmul_grouped_fused_ref(c, g, plan,
                                                          r=failed)
        return ref.entangled_matmul_grouped_ref(c, g, plan.l)

    def econv(x, w, *, plan, fuse_epilogue, failed, blocks, packed=False):
        if packed:
            w = codec.unpack_int8(w, axis=0)
        if fuse_epilogue:
            return ref.entangled_conv1d_fused_ref(x, w, plan, r=failed)
        return ref.entangled_conv1d_ref(x, w, plan.l)

    return {"entangled_matmul": emm, "entangled_matmul_grouped": emmg,
            "entangled_conv1d": econv}


def triton_cuda_stub() -> dict:
    """Placeholder impls dict for the planned Triton/CUDA port.

    Registering it (``ops.register_backend("triton_cuda",
    ops.triton_cuda_stub(), interpret=False)``) reserves the backend
    namespace; calling any op raises with the porting contract. The real
    port replaces each entry with a Triton kernel implementing the same
    entangle-on-load / int32-accumulate / extract-at-flush schedule (see
    the module docstring and ``kernels/entangled_matmul.py``).
    """
    def _todo(op):
        def impl(*a, **k):
            raise NotImplementedError(
                f"triton_cuda backend: {op} is not ported yet. Implement "
                f"the fused schedule (entangle-on-load, int32 VMEM/SMEM "
                f"accumulate, disentangle at the k-flush) and validate "
                f"bit-exactly against repro.kernels.ref — then "
                f"ops.register_backend('triton_cuda', {{...}}) the real "
                f"impls and ship kernels/pretuned/triton_cuda.json")
        return impl

    return {op: _todo(op) for op in REQUIRED_OPS}


register_backend(
    "pallas_tpu", _pallas_impls(interpret=False), interpret=False,
    description="compiled Pallas TPU kernels (MXU int GEMM, fused codec)")
register_backend(
    "interpret_cpu", _pallas_impls(interpret=True), interpret=True,
    description="Pallas interpret mode — CPU validation of the exact "
                "kernel schedules")
register_backend(
    "reference", _ref_impls(), interpret=True,
    description="pure-jnp oracles (XLA-lowered; exactness baseline)")
