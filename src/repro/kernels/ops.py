"""Public jit'd wrappers over the Pallas kernels.

Handles: arbitrary trailing shapes (flattened to the sample axis), padding to
block multiples, backend dispatch (compiled on TPU, interpret=True elsewhere
— the task-mandated CPU validation mode), and plan-aware parameter plumbing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.plan import EntanglePlan
from repro.kernels.checksum import checksum_pallas
from repro.kernels.conv1d import conv1d_causal_pallas
from repro.kernels.disentangle import disentangle_pallas
from repro.kernels.entangle import entangle_pallas
from repro.kernels.entangled_matmul import entangled_matmul_pallas


def _interpret_default(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_to(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def entangle(c: jax.Array, plan: EntanglePlan, *, block_n: int = 1024,
             interpret=None) -> jax.Array:
    """Entangle M streams of any trailing shape ([M, ...] int)."""
    shape = c.shape
    flat = c.reshape(shape[0], -1).astype(jnp.int32)
    padded, n = _pad_to(flat, 1, block_n)
    out = entangle_pallas(
        padded, l=plan.l, block_n=block_n,
        interpret=_interpret_default(interpret),
    )
    return out[:, :n].reshape(shape)


def disentangle(delta: jax.Array, plan: EntanglePlan, *, failed: int | None = None,
                block_n: int = 1024, interpret=None) -> jax.Array:
    """Recover all M outputs from entangled outputs of any trailing shape."""
    shape = delta.shape
    flat = delta.reshape(shape[0], -1).astype(jnp.int32)
    padded, n = _pad_to(flat, 1, block_n)
    out = disentangle_pallas(
        padded, plan=plan, r=0 if failed is None else failed,
        block_n=block_n, interpret=_interpret_default(interpret),
    )
    return out[:, :n].reshape(shape)


def entangled_matmul(c: jax.Array, g: jax.Array, plan: EntanglePlan, *,
                     bb: int = 128, bn: int = 128, bk: int = 128,
                     interpret=None) -> jax.Array:
    """Fused entangle+GEMM: c [M, B, K], g [K, N] -> entangled outputs
    [M, B, N]. Pads B/K/N to block multiples (zero padding is exact for
    integer GEMM)."""
    M, B, K = c.shape
    c32 = c.astype(jnp.int32)
    g32 = g.astype(jnp.int32)
    cp, _ = _pad_to(c32, 1, bb)
    cp, _ = _pad_to(cp, 2, bk)
    gp, _ = _pad_to(g32, 0, bk)
    gp, _ = _pad_to(gp, 1, bn)
    out = entangled_matmul_pallas(
        cp, gp, l=plan.l, bb=bb, bn=bn, bk=bk,
        interpret=_interpret_default(interpret),
    )
    return out[:, :B, : g.shape[1]]


def conv1d_causal(x: jax.Array, w: jax.Array, *, bd: int = 128, bt: int = 512,
                  interpret=None) -> jax.Array:
    """Depthwise causal conv1d: x [B, D, T], w [D, K_f]."""
    B, D, T = x.shape
    xp, _ = _pad_to(x.astype(jnp.int32), 1, bd)
    xp, _ = _pad_to(xp, 2, bt)
    wp, _ = _pad_to(w.astype(jnp.int32), 0, bd)
    out = conv1d_causal_pallas(
        xp, wp, bd=bd, bt=bt, interpret=_interpret_default(interpret)
    )
    return out[:, :D, :T]


def checksum(c: jax.Array, *, block_n: int = 1024, interpret=None) -> jax.Array:
    """Checksum stream r = sum_m c_m for [M, ...] inputs -> [...]."""
    shape = c.shape
    flat = c.reshape(shape[0], -1).astype(jnp.int32)
    padded, n = _pad_to(flat, 1, block_n)
    out = checksum_pallas(
        padded, block_n=block_n, interpret=_interpret_default(interpret)
    )
    return out[0, :n].reshape(shape[1:])
