"""Dispatch layer over the Pallas kernels.

Every public wrapper here handles, uniformly:

  * arbitrary trailing shapes (flattened to the sample axis) and padding to
    block multiples (zero padding is exact for integer LSB ops);
  * backend dispatch — compiled on TPU, ``interpret=True`` elsewhere (the
    task-mandated CPU validation mode);
  * block-size dispatch via the ``blocks`` argument:
      - ``None``: shape-aware defaults (power-of-two, capped at the
        MXU/VPU-aligned 128/512 tiles);
      - a dict: explicit override, merged over the defaults;
      - ``"auto"``: the :mod:`repro.kernels.autotune` subsystem — sweep
        once per (op, shape, backend) key, then cache-hit;
  * codec fusion via ``fuse_epilogue`` on the LSB-op wrappers: ``True``
    returns extracted true outputs from ONE fused pallas_call (entangle ->
    op -> extract, zero intermediate HBM round-trips); ``False`` returns
    entangled outputs for callers that inject failures / persist entangled
    state, to be recovered later with :func:`disentangle`.

The per-kernel legacy block kwargs (``bb=/bn=/bk=``, ``bd=/bt=``,
``block_n=``) remain accepted and act as defaults under ``blocks``.
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core.plan import EntanglePlan
from repro.kernels import autotune as at
from repro.kernels.checksum import checksum_pallas
from repro.kernels.conv1d import conv1d_causal_pallas
from repro.kernels.disentangle import disentangle_pallas
from repro.kernels.entangle import entangle_pallas
from repro.kernels.entangled_conv1d import entangled_conv1d_pallas
from repro.kernels.entangled_matmul import entangled_matmul_pallas

Blocks = Union[None, str, dict]


def _interpret_default(interpret):
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def _pad_to(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def _backend_tag(interpret: bool) -> str:
    return "interpret" if interpret else jax.default_backend()


def _resolve_blocks(op: str, defaults: dict, blocks: Blocks, shape_sig: tuple,
                    interpret: bool, bench, flags: tuple = ()) -> dict:
    """Merge/auto-tune the block sizes for one wrapper call."""
    if blocks is None:
        return defaults
    if isinstance(blocks, dict):
        return {**defaults, **blocks}
    if blocks == "auto":
        return at.tune(op, shape_sig, _backend_tag(interpret), bench,
                       flags=flags)
    raise ValueError(f"blocks must be None, a dict or 'auto', got {blocks!r}")


# --------------------------------------------------------------- codec ------

def _plan_flags(plan: EntanglePlan) -> tuple:
    """Autotune key component for the codec parameters: the Horner depth
    and temp mode change the epilogue cost, so winners must not be shared
    across plans that merely agree on M and shapes."""
    return (f"l{plan.l}", plan.temp)


def _codec_pass(op: str, kernel_call, x: jax.Array, block_n: int,
                blocks: Blocks, interpret, flags: tuple = ()):
    """Shared flatten -> pad -> resolve/tune -> kernel path for the
    elementwise [M, N] codec sweeps. ``kernel_call(padded, bn, interp)``
    invokes the kernel; returns (out, valid_n, original_shape)."""
    shape = x.shape
    flat = x.reshape(shape[0], -1).astype(jnp.int32)
    interp = _interpret_default(interpret)

    def bench(bl):
        padded, _ = _pad_to(flat, 1, bl["block_n"])
        return lambda: kernel_call(padded, bl["block_n"], interp)

    bl = _resolve_blocks(op, {"block_n": block_n}, blocks,
                         (shape[0], flat.shape[1]), interp, bench,
                         flags=flags)
    padded, n = _pad_to(flat, 1, bl["block_n"])
    return kernel_call(padded, bl["block_n"], interp), n, shape


def entangle(c: jax.Array, plan: EntanglePlan, *, block_n: int = 1024,
             blocks: Blocks = None, interpret=None) -> jax.Array:
    """Entangle M streams of any trailing shape ([M, ...] int)."""
    out, n, shape = _codec_pass(
        "entangle",
        lambda p, bn, it: entangle_pallas(p, l=plan.l, block_n=bn,
                                          interpret=it),
        c, block_n, blocks, interpret, flags=_plan_flags(plan))
    return out[:, :n].reshape(shape)


def disentangle(delta: jax.Array, plan: EntanglePlan, *,
                failed: Optional[int] = None, block_n: int = 1024,
                blocks: Blocks = None, interpret=None) -> jax.Array:
    """Recover all M outputs from entangled outputs of any trailing shape."""
    r = 0 if failed is None else failed
    out, n, shape = _codec_pass(
        "disentangle",
        lambda p, bn, it: disentangle_pallas(p, plan=plan, r=r, block_n=bn,
                                             interpret=it),
        delta, block_n, blocks, interpret, flags=_plan_flags(plan))
    return out[:, :n].reshape(shape)


def checksum(c: jax.Array, *, block_n: int = 1024, blocks: Blocks = None,
             interpret=None) -> jax.Array:
    """Checksum stream r = sum_m c_m for [M, ...] inputs -> [...]."""
    out, n, shape = _codec_pass(
        "checksum",
        lambda p, bn, it: checksum_pallas(p, block_n=bn, interpret=it),
        c, block_n, blocks, interpret)
    return out[0, :n].reshape(shape[1:])


# ------------------------------------------------------------- LSB ops ------

def entangled_matmul(c: jax.Array, g: jax.Array, plan: EntanglePlan, *,
                     fuse_epilogue: bool = False,
                     failed: Optional[int] = None,
                     bb: int = 128, bn: int = 128, bk: int = 128,
                     blocks: Blocks = None, interpret=None) -> jax.Array:
    """Fused entangle+GEMM[+extract]: c [M, B, K], g [K, N] int.

    ``fuse_epilogue=False`` -> entangled products [M, B, N] (recover later
    via :func:`disentangle`). ``fuse_epilogue=True`` -> true products, the
    codec never leaving the kernel; ``failed`` statically excludes one
    stream's accumulator from the in-kernel extraction.
    """
    M, B, K = c.shape
    N = g.shape[1]
    c32 = c.astype(jnp.int32)
    g32 = g.astype(jnp.int32)
    interp = _interpret_default(interpret)
    r = 0 if failed is None else failed

    def call(bl, cc, gg):
        cp, _ = _pad_to(cc, 1, bl["bb"])
        cp, _ = _pad_to(cp, 2, bl["bk"])
        gp, _ = _pad_to(gg, 0, bl["bk"])
        gp, _ = _pad_to(gp, 1, bl["bn"])
        return entangled_matmul_pallas(
            cp, gp, plan=plan, fuse_epilogue=fuse_epilogue, failed=r,
            bb=bl["bb"], bn=bl["bn"], bk=bl["bk"], interpret=interp)

    bl = _resolve_blocks(
        "entangled_matmul", {"bb": bb, "bn": bn, "bk": bk}, blocks,
        (M, B, K, N), interp, lambda b: (lambda: call(b, c32, g32)),
        flags=_matmul_flags(plan, fuse_epilogue))
    out = call(bl, c32, g32)
    return out[:, :B, :N]


def _matmul_flags(plan: EntanglePlan, fuse_epilogue: bool) -> tuple:
    """Autotune flags for the fused GEMM — single source of truth for the
    wrapper's tune call and the startup warm's cache lookup."""
    return _plan_flags(plan) + (("fused",) if fuse_epilogue else ())


def warm_entangled_matmul(M: int, B: int, K: int, N: int, plan: EntanglePlan,
                          *, fuse_epilogue: bool = True,
                          interpret=None) -> dict:
    """Eagerly autotune the fused GEMM for one (M, B, K, N) serving shape.

    The serving engine calls this at startup for every shape in its census:
    the sweep runs HERE, eagerly on real buffers, so that ``blocks="auto"``
    inside the engine's jitted decode step is a pure in-process cache hit
    (a sweep during tracing would time tracers, not kernels). ``failed`` is
    deliberately not part of the autotune key, so one warm covers healthy
    and every fail-stop-injected variant. Returns the winning block sizes.
    """
    c = jnp.zeros((M, B, K), jnp.int32)
    g = jnp.zeros((K, N), jnp.int32)
    entangled_matmul(c, g, plan, fuse_epilogue=fuse_epilogue, blocks="auto",
                     interpret=interpret)
    interp = _interpret_default(interpret)
    key = at.cache_key("entangled_matmul", (M, B, K, N),
                       _backend_tag(interp), _matmul_flags(plan, fuse_epilogue))
    return at.get_cache().get(key) or {}


def entangled_conv1d(x: jax.Array, w: jax.Array, plan: EntanglePlan, *,
                     fuse_epilogue: bool = False,
                     failed: Optional[int] = None,
                     bd: int = 128, bt: int = 512,
                     blocks: Blocks = None, interpret=None) -> jax.Array:
    """Fused entangle+depthwise-causal-conv[+extract]: x [M, B, D, T],
    w [D, K_f] int. Same fusion semantics as :func:`entangled_matmul`."""
    M, B, D, T = x.shape
    kf = w.shape[1]
    x32 = x.astype(jnp.int32)
    w32 = w.astype(jnp.int32)
    if kf == 1:  # kernel needs a halo; a zero leading tap is exact
        w32 = jnp.pad(w32, ((0, 0), (1, 0)))
        kf = 2
    interp = _interpret_default(interpret)
    r = 0 if failed is None else failed

    def call(bl, xx, ww):
        xp, _ = _pad_to(xx, 2, bl["bd"])
        xp, _ = _pad_to(xp, 3, bl["bt"])
        wp, _ = _pad_to(ww, 0, bl["bd"])
        return entangled_conv1d_pallas(
            xp, wp, plan=plan, fuse_epilogue=fuse_epilogue, failed=r,
            bd=bl["bd"], bt=bl["bt"], interpret=interp)

    bl = _resolve_blocks(
        "entangled_conv1d", {"bd": bd, "bt": bt}, blocks,
        (M, B, D, T, kf), interp, lambda b: (lambda: call(b, x32, w32)),
        flags=_plan_flags(plan) + (("fused",) if fuse_epilogue else ()))
    out = call(bl, x32, w32)
    return out[:, :, :D, :T]


def conv1d_causal(x: jax.Array, w: jax.Array, *, bd: int = 128, bt: int = 512,
                  blocks: Blocks = None, interpret=None) -> jax.Array:
    """Depthwise causal conv1d (unentangled): x [B, D, T], w [D, K_f]."""
    B, D, T = x.shape
    x32 = x.astype(jnp.int32)
    w32 = w.astype(jnp.int32)
    if w32.shape[1] == 1:  # kernel's halo slice needs K_f >= 2; a zero
        w32 = jnp.pad(w32, ((0, 0), (1, 0)))  # leading tap is exact
    interp = _interpret_default(interpret)

    def call(bl, xx, ww):
        xp, _ = _pad_to(xx, 1, bl["bd"])
        xp, _ = _pad_to(xp, 2, bl["bt"])
        wp, _ = _pad_to(ww, 0, bl["bd"])
        return conv1d_causal_pallas(
            xp, wp, bd=bl["bd"], bt=bl["bt"], interpret=interp)

    bl = _resolve_blocks(
        "conv1d", {"bd": bd, "bt": bt}, blocks,
        (B, D, T, w.shape[1]), interp, lambda b: (lambda: call(b, x32, w32)))
    out = call(bl, x32, w32)
    return out[:, :D, :T]
