"""Pallas kernel layer: the paper's codec fused into the compute pass.

Architecture (one PR-sized map; details in each module's docstring):

  codec.py             register-level codec math (entangle_block,
                       disentangle_rows/_block incl. the dualword path) —
                       the ONE implementation shared by every kernel below
  entangle.py          standalone entangle pass ([M, N] VPU sweep)
  disentangle.py       standalone disentangle / fail-stop recovery pass
  checksum.py          checksum-ABFT baseline stream
  entangled_matmul.py  fused entangle -> int GEMM -> extract, one
                       pallas_call; M streams fully resident per block
  conv1d.py            unentangled depthwise causal conv1d
  entangled_conv1d.py  fused entangle -> conv1d -> extract
  autotune.py          block-size autotuner: per-(op, shape, backend) sweep
                       with in-process + JSON-file winner cache
  ops.py               the dispatch layer — padding, backend selection,
                       `blocks` (None | dict | "auto") and `fuse_epilogue`
                       dispatch; the only module callers import
  ref.py               pure-jnp oracles (exact-equality targets for tests)

Adding a new LSB kernel behind ops.py:

  1. implement the schedule in ``<op>.py``, importing its codec math from
     codec.py (entangle on load, optional disentangle at the flush — never
     a separate HBM sweep);
  2. add the jnp oracle to ref.py and exact-equality tests (including each
     failed-stream index r and a dualword plan);
  3. add a candidate table entry in autotune.candidates_for and a wrapper
     in ops.py following the `blocks`/`fuse_epilogue` signature;
  4. extend benchmarks/kernel_micro.py with its fused-vs-separate bytes
     model so the overhead trajectory stays tracked in BENCH_*.json.
"""
