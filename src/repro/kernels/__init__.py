"""Pallas kernel layer: the paper's codec fused into the compute pass.

Architecture (one PR-sized map; details in each module's docstring):

  codec.py             register-level codec math (entangle_block,
                       disentangle_rows/_block incl. the dualword path) —
                       the ONE implementation shared by every kernel below
  entangle.py          standalone entangle pass ([M, N] VPU sweep)
  disentangle.py       standalone disentangle / fail-stop recovery pass
  checksum.py          checksum-ABFT baseline stream
  entangled_matmul.py  fused entangle -> int GEMM -> extract, one
                       pallas_call; M streams fully resident per block
  conv1d.py            unentangled depthwise causal conv1d
  entangled_conv1d.py  fused entangle -> conv1d -> extract
  autotune.py          block-size autotuner: per-(op, shape, backend) sweep
                       with in-process + JSON-file winner cache
  ops.py               the dispatch layer — padding, backend selection,
                       `blocks` (None | dict | "auto") and `fuse_epilogue`
                       dispatch; the only module callers import
  ref.py               pure-jnp oracles (exact-equality targets for tests)

Adding a new LSB kernel behind ops.py:

  1. implement the schedule in ``<op>.py``, importing its codec math from
     codec.py (entangle on load, optional disentangle at the flush — never
     a separate HBM sweep);
  2. add the jnp oracle to ref.py and exact-equality tests (including each
     failed-stream index r and a dualword plan);
  3. add a candidate table entry in autotune.candidates_for and a wrapper
     in ops.py following the `blocks`/`fuse_epilogue` signature;
  4. extend benchmarks/kernel_micro.py with its fused-vs-separate bytes
     model so the overhead trajectory stays tracked in BENCH_*.json.

How to protect a new GEMM (the repro.ft subsystem):

  1. find the projection's ``layers.dense`` call (or raw einsum) and give
     it a site name ``"<category>.<proj>"`` — category ``qkv`` (mixer
     input projections), ``mlp`` (FFN projections incl. routers) or a new
     one added to ``repro.ft.protected.SCOPES``. For a ``dense`` call,
     protection is one kwarg: ``dense(p["w_new"], h, ft=ft,
     site="qkv.new")``; for a raw einsum, guard with
     ``ft is not None and ft.protects(site)`` and call
     ``ft.matmul(site, x, w)`` (returns float32 — cast back to the
     surrounding activation dtype).
  2. thread the ``ft`` kwarg from the block's ``apply`` down to the call
     if the site lives in a block that did not previously take it
     (``transformer.apply_stack`` already passes ``ft`` to every block).
  3. nothing else: the site's :class:`repro.ft.PlanRegistry` entry (plan +
     block sizes) is created at trace time, ``ServeEngine.warm_autotune``
     discovers the new shape through its census-only abstract trace and
     pre-sweeps it for ``blocks='auto'``, and ``step(failed_group=r)``
     reaches it automatically.
  4. extend the scope x failure-injection matrix test
     (tests/test_serve_engine.py::test_ft_scope_failstop_bit_identical)
     if the site introduced a new category, and regenerate the pre-tuned
     seed cache (``kernels/pretuned/``) if the new shape should cold-hit
     in CI.

The quantization policy (int8 weights, eq.-13-budgeted activations) is
shared — see repro/ft/quantize.py; exactness of the roll-forward does not
depend on block sizes, plan choice or backend, only on both runs taking
the same protected path.
"""
