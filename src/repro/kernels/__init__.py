"""Pallas kernel layer: the paper's codec fused into the compute pass.

Architecture (one PR-sized map; details in each module's docstring):

  codec.py             register-level codec math (entangle_block,
                       disentangle_rows/_block incl. the dualword path) —
                       the ONE implementation shared by every kernel below
  entangle.py          standalone entangle pass ([M, N] VPU sweep)
  disentangle.py       standalone disentangle / fail-stop recovery pass
  checksum.py          checksum-ABFT baseline stream
  entangled_matmul.py  fused entangle -> int GEMM -> extract, one
                       pallas_call; M streams fully resident per block
  entangled_matmul_grouped.py
                       the grouped (MoE per-expert) variant: E independent
                       GEMMs, one kernel call, expert axis on the grid
  conv1d.py            unentangled depthwise causal conv1d
  entangled_conv1d.py  fused entangle -> conv1d -> extract
  autotune.py          block-size autotuner: per-(op, shape, backend,
                       flags) sweep with in-process + JSON-file winner
                       cache; keys are backend-namespaced; hardened loader
                       (a corrupt cache degrades to the pretuned seed)
  pretuned/            shipped seed caches, one JSON per backend namespace
  ops.py               the dispatch layer — padding, the BACKEND REGISTRY
                       (register_backend: pallas_tpu / interpret_cpu /
                       reference shipped; Triton/CUDA stub documented),
                       `blocks` (None | dict | "auto") and `fuse_epilogue`
                       dispatch; the only module callers import
  ref.py               pure-jnp oracles (exact-equality targets for tests;
                       also registered as the "reference" backend)

Adding a new LSB kernel behind ops.py:

  1. implement the schedule in ``<op>.py``, importing its codec math from
     codec.py (entangle on load, optional disentangle at the flush — never
     a separate HBM sweep);
  2. add the jnp oracle to ref.py and exact-equality tests (including each
     failed-stream index r and a dualword plan);
  3. add a candidate table entry in autotune.candidates_for, a wrapper in
     ops.py following the `blocks`/`fuse_epilogue`/`backend` signature,
     and an entry in every registered backend's impls dict (the op name
     joins ops.REQUIRED_OPS);
  4. extend benchmarks/kernel_micro.py with its fused-vs-separate bytes
     model so the overhead trajectory stays tracked in BENCH_*.json.

Porting the kernels to a new backend (Triton/CUDA):
see the "Porting to Triton/CUDA" section of the ops.py docstring —
``ops.register_backend(name, impls)`` with the three required ops, keyed
autotune namespace, optional ``pretuned/<name>.json`` seed cache.

How to protect a new GEMM (the repro.ft subsystem, v2 plan-compile flow):

  1. find the projection's ``layers.dense`` call (or raw einsum) and give
     it a site name ``"<category>.<proj>"`` — category ``qkv`` (mixer
     input projections), ``mlp`` (FFN projections incl. routers), ``out``
     (mixer output projections), ``moe`` (per-expert grouped GEMMs), or a
     new one added to ``repro.ft.protected.SCOPES``. For a ``dense``
     call, protection is one kwarg: ``dense(p["w_new"], h, ft=ft,
     site="qkv.new")``; for a raw einsum, guard with
     ``ft is not None and ft.protects(site)`` and call
     ``ft.matmul(site, x, w)`` — or ``ft.matmul_grouped(site, x, w)`` for
     per-expert stacks x [..., E, C, K] against w [E, K, N] (returns
     float32 — cast back to the surrounding activation dtype).
  2. register the site's weight for the startup quantization hoist: add
     its param-dict key to ``repro.ft.plans.PROTECTED_WEIGHT_KEYS`` (if
     the key is new) so ``prepare_params`` installs the pre-quantized
     ``q8`` copy at engine startup — PACKED 4 int8 lanes per int32 word
     along the contraction axis by default (``packed=True``; the kernels
     unpack on load and executors infer packedness from the axis length,
     so the call site never mentions it); at the call site prefer the
     ``q8`` entry when present (see ``layers.dense`` — one line).
  2b. if the new site shares its input activations with existing sites
     (a FANOUT group like attention Q/K/V or MLP gate/up), route the
     group through ONE ``dense_fanout(ps, x, ft=ft, sites=(...))`` call
     instead of per-site ``dense`` calls: the group then shares a single
     quantize + group-permute codec pass (the dominant non-GEMM cost)
     and the census marks it chainable on the compiled plans
     (``engine.plans.chains``). For strictly CONSECUTIVE linear GEMMs,
     ``repro.ft.protected.entangled_chain`` runs the whole chain in the
     entangled domain — one entangle, N GEMMs, one extract — whenever
     ``repro.ft.quantize.chain_budget`` grants headroom (it falls back
     to per-hop extraction when not).
  3. thread the ``ft`` kwarg from the block's ``apply`` down to the call
     if the site lives in a block that did not previously take it
     (``transformer.apply_stack`` already passes ``ft`` to every block).
  4. nothing else: the engine's census-only abstract trace discovers the
     new shape at startup, ``repro.ft.compile_plans`` freezes it into the
     immutable per-site plan set, ``warm_autotune`` pre-sweeps it for
     ``blocks='auto'``, and ``step(failed_group=r)`` reaches it
     automatically.
  5. extend the scope x failure-injection matrix test
     (tests/test_serve_engine.py::test_ft_scope_failstop_bit_identical —
     or the grouped MoE twin) if the site introduced a new category, and
     regenerate the pre-tuned seed cache (``kernels/pretuned/``) if the
     new shape should cold-hit in CI.

The quantization policy (int8 weights — hoisted to startup by
``prepare_params`` — and eq.-13-budgeted, PER-ROW-scaled activations) is
shared — see repro/ft/quantize.py; exactness of the roll-forward does not
depend on block sizes, plan choice or backend, only on both runs taking
the same protected path.

Steady-state serving note: mid-flight slot refill (repro.serve) never
introduces new kernel shapes — a refilled admission batch replays one of
the startup census'd [Bp, chunk] programs, so the compiled plans, block
sizes and pretuned winners that served the first wave serve every refill
(``CompiledPlans.misses`` stays 0, no mid-serve sweep). When adding chunk
widths or prefill buckets that change the census, regenerate
``pretuned/interpret_cpu.json`` so cold refill starts stay sweep-free
(gated by tests/test_ft_subsystem.py::test_pretuned_seed_cache_cold_hit).

Token-packed serving note (``ServeConfig.token_budget > 0``): the packed
step gathers up to token_budget TRUE prompt tokens from every in-flight
admission batch into ONE [Rp, Cp] program (Rp = token_budget //
prefill_chunk rows, Cp = prefill_chunk columns), so the whole admission
pipeline compiles to a single prefill shape — the census holds exactly
one entry and the protected-GEMM registry one row-count (token_budget)
per site. That density is also why the FT overhead per USEFUL token
drops: the entangled codec (quantize + entangle + disentangle) costs
linearly in program rows, and packed rows carry no bucket padding, so
every codec row is a real token instead of pad. Tune token_budget as the
largest multiple of prefill_chunk the accelerator keeps dense (it must
not exceed max_batch * prefill_chunk — each row needs a staging slot);
raising it amortizes per-call overhead, lowering it bounds the
admission work per step and keeps decode ITL flat. Packed shapes (rows
= token_budget) are seeded in ``pretuned/interpret_cpu.json`` alongside
the chunked ones — regenerate when changing token_budget geometry.
"""
