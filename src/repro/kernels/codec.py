"""Register-level codec math shared by every fused Pallas kernel.

One implementation of the paper's codec, written over *register values*
(jnp arrays already loaded from VMEM refs) so the same code runs

  * inside the standalone entangle/disentangle kernels,
  * as the load-prologue / flush-epilogue of the fused GEMM and conv1d
    kernels (entangle-on-load, extract-at-flush),
  * in the jnp oracles.

``entangle_block`` is eq. (14/15): one shift-add per element against the
cyclic predecessor row. ``disentangle_rows`` is eq. (16-19): the Horner
telescoping sum (int32 single-word or dual-word per paper Remark 1), the
sign-extended bit-field split of d_r / d_q, and the eq. (19) recovery
chain. All ops are shifts/adds on VPU integer lanes — no multiplies, no
HBM traffic.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import wideint
from repro.core.plan import EntanglePlan


def entangle_block(c: jax.Array, l: int) -> jax.Array:
    """eps_m = (c_{(m-1) mod M} << l) + c_m over leading axis of ``c``."""
    return jnp.left_shift(jnp.roll(c, 1, axis=0), l) + c


# ---------------------------------------------------------------------------
# int8 lane packing — 4 int8 values per int32 word
#
# The startup-quantized q8 weight copies are int8-valued but ride the
# kernels' int32 container, costing 4x their true bytes in HBM plus a
# 4x-wide sweep per protected GEMM. Packing stores 4 consecutive values
# along the contraction axis in one int32 word (lane j in bits
# [8j, 8j+8)); the fused kernels unpack on load in VMEM registers with
# two shifts per lane — arithmetic right-shift sign-extends, so the
# roundtrip is bit-exact over the full int8 range.
# ---------------------------------------------------------------------------

PACK_LANES = 4  # int8 lanes per int32 word


def pack_int8(x: jax.Array, axis: int = -2) -> jax.Array:
    """Pack int8-valued int32 ``x`` 4-to-1 along ``axis``.

    ``axis`` is zero-padded to a multiple of :data:`PACK_LANES` (zero packs
    and unpacks exactly, so padding never perturbs a GEMM). Values must be
    in [-128, 127]; out-of-range values are truncated mod 256.
    """
    axis = axis % x.ndim
    n = x.shape[axis]
    pad = (-n) % PACK_LANES
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        x = jnp.pad(x, widths)
    lanes = jnp.moveaxis(x, axis, -1).reshape(
        *[s for a, s in enumerate(x.shape) if a != axis],
        (n + pad) // PACK_LANES, PACK_LANES)
    word = jnp.zeros(lanes.shape[:-1], jnp.int32)
    for j in range(PACK_LANES):
        word = word + jnp.left_shift(
            jnp.bitwise_and(lanes[..., j].astype(jnp.int32), 0xFF), 8 * j)
    return jnp.moveaxis(word, -1, axis)


def unpack_int8(p: jax.Array, axis: int = -2, n: Optional[int] = None
                ) -> jax.Array:
    """Inverse of :func:`pack_int8`: expand ``axis`` 1-to-4, sign-extended.

    ``n`` truncates the unpacked axis back to its original length (the
    pack may have zero-padded it to a multiple of :data:`PACK_LANES`).
    """
    axis = axis % p.ndim
    lanes = [jnp.right_shift(jnp.left_shift(p, 24 - 8 * j), 24)
             for j in range(PACK_LANES)]
    out = jnp.stack(lanes, axis=axis + 1)
    shape = list(p.shape)
    shape[axis] = p.shape[axis] * PACK_LANES
    out = out.reshape(shape)
    if n is not None and n != out.shape[axis]:
        out = jax.lax.slice_in_dim(out, 0, n, axis=axis)
    return out


def disentangle_rows(
    delta_rows: Sequence[jax.Array],
    plan: EntanglePlan,
    r: int = 0,
) -> list[jax.Array]:
    """Recover all M outputs from the M entangled rows, never reading row r.

    ``delta_rows[m]`` is the entangled output of stream m (any common
    shape). The failed/excluded index ``r`` is static. Returns the M
    recovered outputs in original stream order.
    """
    M, l = plan.M, plan.l
    assert len(delta_rows) == M, (len(delta_rows), M)
    r = r % M
    B = (M - 1) * l
    sign = -1 if (M % 2) else 1  # (-1)^M
    q = (r + M - 1) % M

    deltas = [delta_rows[(r + 1 + m) % M] for m in range(M - 1)]

    if plan.temp == "dualword":
        t = wideint.widen(deltas[0])
        for j, d in enumerate(deltas[1:], start=2):
            t = wideint.shl(t, l)
            t = (
                wideint.sub(t, wideint.widen(d))
                if (j % 2 == 0)
                else wideint.add(t, wideint.widen(d))
            )
        t_lo = wideint.extract_low_signed(t, B)
        d_q = (sign * t_lo).astype(jnp.int32)
        d_r = wideint.shr_exact_to_i32(wideint.sub(t, wideint.widen(t_lo)), B)
    else:  # single int32 word (valid when plan.temp_bits <= 32)
        t = deltas[0]
        for j, d in enumerate(deltas[1:], start=2):
            t = jnp.left_shift(t, l)
            t = (t - d) if (j % 2 == 0) else (t + d)
        shift = 32 - B
        t_lo = jnp.right_shift(jnp.left_shift(t, shift), shift)
        d_q = (sign * t_lo).astype(jnp.int32)
        d_r = jnp.right_shift(t - t_lo, B)

    out: list[Optional[jax.Array]] = [None] * M
    out[r], out[q] = d_r, d_q
    for m in range(1, M - 1):  # eq. (19) chain
        idx = (r + m) % M
        out[idx] = delta_rows[idx] - jnp.left_shift(out[(r + m - 1) % M], l)
    return out  # type: ignore[return-value]


def disentangle_block(
    delta: jax.Array, plan: EntanglePlan, r: int = 0
) -> jax.Array:
    """:func:`disentangle_rows` over the leading axis of a stacked block."""
    rows = [delta[m] for m in range(plan.M)]
    return jnp.stack(disentangle_rows(rows, plan, r), axis=0)
