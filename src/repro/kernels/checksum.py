"""Pallas TPU kernel: checksum-stream generation (ABFT baseline, paper eq. 4).

Elementwise sum over the M-stream axis producing the (M+1)-th checksum
stream. Exists so the baseline's generation cost is measured with the same
kernel discipline as entanglement (paper Sec. V generates checksums with
AVX2 too).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _checksum_kernel(c_ref, out_ref):
    out_ref[...] = jnp.sum(c_ref[...], axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def checksum_pallas(
    c: jax.Array, *, block_n: int = 1024, interpret: bool = False
) -> jax.Array:
    """r = sum_m c_m for c:[M, N] int32 -> [1, N] int32."""
    M, N = c.shape
    grid = (N // block_n,)
    return pl.pallas_call(
        _checksum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((M, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, N), jnp.int32),
        interpret=interpret,
    )(c)
