"""End-to-end driver: train a ~100M llama-style model for a few hundred
steps on the synthetic corpus, with the paper's technique protecting the
gradient path (entangled int32 gradient sync), async checkpointing, a
mid-run injected fail-stop, and a kill/resume drill.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--small]
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig
from repro.data.synthetic import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainConfig
from repro.train.trainer import LoopConfig, train_loop


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="llama-100m", family="dense", n_layers=8, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab_size=32000, head_dim=64,
        rope_theta=5e5, tie_embeddings=True,
    )


def model_small() -> ModelConfig:
    return ModelConfig(
        name="llama-8m", family="dense", n_layers=4, d_model=256,
        n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=4096, head_dim=64,
        tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="8M params (CPU-friendly smoke)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--seq", type=int, default=0)
    args = ap.parse_args()

    cfg = model_small() if args.small else model_100m()
    seq = args.seq or (128 if args.small else 512)
    tcfg = TrainConfig(
        adamw=AdamWConfig(lr=1e-3, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps),
        grad_sync="entangle",  # the paper's technique on the gradient path
        ft_M=4,
        max_seq=seq,
    )
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                      batch_size=4 if args.small else 8)
    loop = LoopConfig(
        total_steps=args.steps,
        ckpt_every=max(args.steps // 4, 1),
        ckpt_dir=args.ckpt_dir,
        log_every=max(args.steps // 20, 1),
        fail_block_at_step=args.steps // 2,  # fail-stop drill mid-training
    )
    n_params = sum(
        p.size for p in __import__("jax").tree.leaves(
            __import__("jax").eval_shape(
                lambda k: __import__("repro.models", fromlist=["get_model"])
                .get_model(cfg).init(k, cfg, seq),
                __import__("jax").random.PRNGKey(0))))
    print(f"[train_lm] {cfg.name}: {n_params/1e6:.1f}M params, seq={seq}, "
          f"grad_sync=entangle(M={tcfg.ft_M}), "
          f"fail-stop injected at step {loop.fail_block_at_step}")
    state, losses = train_loop(cfg, tcfg, dcfg, loop)
    print(f"[train_lm] loss {losses[0]:.3f} -> {losses[-1]:.3f} over "
          f"{len(losses)} steps (fail-stop step caused no disruption)")
    assert losses[-1] < losses[0], "model did not learn"


if __name__ == "__main__":
    main()
