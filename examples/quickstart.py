"""Quickstart: the paper's mechanism in 60 lines.

Entangles M=3 integer streams, runs the paper's experimental op (integer
convolution) directly on the entangled streams, kills one stream, and
recovers every result exactly from the survivors — no recomputation.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import FTConfig, get_op, make_plan, run_protected
from repro.core.entangle import disentangle, entangle

conv = get_op("conv").apply  # exact integer convolution

rng = np.random.default_rng(0)

# --- plan: M=3 streams, 32-bit integers (paper Table I row 1) --------------
plan = make_plan(M=3, w=32)
print(f"plan: M={plan.M} l={plan.l} k={plan.k} "
      f"output budget ±{plan.max_output_magnitude} ({plan.output_bits} bits)")

# --- three integer streams + an integer convolution kernel ------------------
c = jnp.asarray(rng.integers(-100, 100, size=(3, 4096)).astype(np.int32))
g = jnp.asarray(rng.integers(-20, 20, size=(64,)).astype(np.int32))

# --- entangle (eq. 6): in-place, no extra streams ---------------------------
eps = entangle(c, plan)
print(f"entangled {c.shape} -> {eps.shape} (same storage, +{plan.l}-bit shift)")

# --- the op runs directly on entangled data ---------------------------------
delta = jnp.stack([conv(eps[m], g) for m in range(3)])

# --- fail-stop: core 1 never returns; recover from the other two (eq. 10) ---
survivors_only = delta.at[1].set(-12345678)  # poison the lost stream
recovered = disentangle(survivors_only, plan, failed=1)

truth = jnp.stack([conv(c[m], g) for m in range(3)])
assert (np.asarray(recovered) == np.asarray(truth)).all()
print("fail-stop on stream 1: all 3 outputs recovered EXACTLY from 2 streams")

# --- one-liner engine with the checksum-ABFT baseline for comparison --------
for mode in ("entangle", "checksum", "mr"):
    out, rep = run_protected("conv", c, g, FTConfig(mode=mode, M=3), failed=0)
    ok = (np.asarray(out) == np.asarray(truth)).all()
    extra = {"entangle": "0 extra cores", "checksum": "1 extra core",
             "mr": "M extra cores"}[mode]
    print(f"  {mode:9s}: recovered={ok}  cost: {extra}")
