"""Fail-stop mitigation tour: all three recovery families (the paper's
entanglement, checksum-ABFT, modular redundancy) across every LSB op class,
with overhead accounting, SDC detection, and entangled storage recovery.

    PYTHONPATH=src python examples/failstop_demo.py
"""
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import FTConfig, make_plan, run_protected, entangle
from repro.core import sdc
from repro.data.pipeline import TokenShardStore

rng = np.random.default_rng(1)
M = 4


def main():
    c = jnp.asarray(rng.integers(-50, 50, size=(M, 1 << 16)).astype(np.int32))
    ops = [
        ("scale", jnp.int32(9)),
        ("add", jnp.int32(-3)),
        ("conv", jnp.asarray(rng.integers(-10, 10, (33,)).astype(np.int32))),
        ("dot", jnp.asarray(rng.integers(-4, 4, (1 << 16,)).astype(np.int32))),
        ("permute", jnp.asarray(rng.permutation(1 << 16))),
    ]

    print(f"{'op':10s} {'family':10s} {'recovered':9s} {'extra cores':11s}")
    for opname, g in ops:
        truth, _ = run_protected(opname, c, g, FTConfig(mode="none", M=M))
        for mode, extra in (("entangle", 0), ("checksum", 1), ("mr", M)):
            failed = int(rng.integers(0, M))
            out, rep = run_protected(opname, c, g, FTConfig(mode=mode, M=M),
                                     failed=failed)
            ok = bool((np.asarray(out) == np.asarray(truth)).all())
            print(f"{opname:10s} {mode:10s} {str(ok):9s} {extra:11d}")
            assert ok

    # --- timing: protection overhead on a big conv (paper Fig. 2 shape) -----
    big = jnp.asarray(rng.integers(-30, 30, size=(M, 200_000)).astype(np.int32))
    g = jnp.asarray(rng.integers(-4, 4, (1000,)).astype(np.int32))

    def timed(mode):
        cfg = FTConfig(mode=mode, M=M)
        fn = jax.jit(lambda c: run_protected("conv", c, g, cfg)[0])
        jax.block_until_ready(fn(big))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(big))
        return time.perf_counter() - t0

    t_none = timed("none")
    for mode in ("entangle", "checksum"):
        t = timed(mode)
        print(f"[overhead] {mode:9s}: +{(t/t_none-1)*100:5.1f}% vs "
              f"failure-intolerant ({t_none*1e3:.0f} ms)")

    # --- SDC detection (paper Remark 4, implemented) -------------------------
    plan = make_plan(M, 32)
    delta = entangle(c[:, :1024], plan)
    corrupted = delta.at[2, 100].add(123456789)
    mask = np.asarray(sdc.detect(corrupted, plan))
    blame = np.asarray(sdc.localize(corrupted, plan))
    print(f"[sdc] silent corruption detected at position {mask.nonzero()[0]}, "
          f"blamed stream {blame[100]} (truth: 2)")

    # --- entangled storage: lose a shard file, keep the data ----------------
    with tempfile.TemporaryDirectory() as d:
        store = TokenShardStore(d, M=M)
        toks = rng.integers(0, 65000, size=(4, 4096)).astype(np.int32)
        paths = store.write_group("corpus", toks)
        paths[3].unlink()  # disk failure
        assert np.array_equal(store.read_group("corpus"), toks)
        print("[storage] token shard group survived a lost file "
              "(entangled at-rest, op=identity)")


if __name__ == "__main__":
    main()
