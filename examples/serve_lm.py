"""Serving example: batched requests through the slot engine, with the
entangled int8 logits projection protecting M=4 request groups, plus a
deadline-straggler drill using the host-side DeadlineExecutor.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.ft_logits import ft_logits, quantize_head
from repro.train.straggler import DeadlineExecutor

rng = np.random.default_rng(0)


def main():
    cfg = get_smoke_config("llama3.2-1b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg, max_seq=128)

    # --- 1) batched request serving ----------------------------------------
    eng = ServeEngine(cfg, ServeConfig(max_batch=4, max_seq=128), params)
    for r in range(8):
        eng.submit(Request(rid=r,
                           prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                           max_new=8))
    t0 = time.monotonic()
    done = eng.run_to_completion()
    print(f"[serve_lm] {len(done)} requests served in "
          f"{time.monotonic()-t0:.1f}s; sample output: {list(done[0].out[:6])}")

    # --- 2) entangled int8 logits across M=4 request groups ----------------
    B, D = 8, cfg.d_model
    h = jnp.asarray(rng.normal(size=(B, D)).astype(np.float32))
    head = jnp.asarray(rng.normal(size=(D, cfg.vocab_size)).astype(np.float32) * 0.02)
    hq, ws = quantize_head(head)
    healthy = ft_logits(h, hq, ws, M=4)
    for fg in range(4):
        out = ft_logits(h, hq, ws, M=4, failed_group=fg)
        assert np.array_equal(np.asarray(out), np.asarray(healthy))
    agree = float(jnp.mean((jnp.argmax(healthy, -1) ==
                            jnp.argmax(h @ head, -1)).astype(jnp.float32)))
    print(f"[serve_lm] entangled int8 logits: bit-identical under any single "
          f"group fail-stop; argmax agreement with f32 head: {agree:.2f}")

    # --- 3) straggler-as-fail-stop drill ------------------------------------
    def group_work(delay):
        def fn():
            time.sleep(delay)
            return "logits"
        return fn

    ex = DeadlineExecutor(deadline_s=0.25)
    results = ex.run([group_work(0.01), group_work(0.02),
                      group_work(5.0), group_work(0.015)])  # group 2 hangs
    failed = DeadlineExecutor.failed_index(results)
    print(f"[serve_lm] deadline drill: group {failed} missed the deadline -> "
          f"rolled forward via disentanglement (see ft_logits above); "
          f"no request waited for the straggler")
    assert failed == 2


if __name__ == "__main__":
    main()
