"""Serving example: batched continuous-batching engine with the entangled
int8 logits projection protecting M=4 request groups ON the decode hot path
(one fused GEMM per engine step, slot -> group = slot % M), plus a
deadline-straggler drill using the host-side DeadlineExecutor.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import (PerSlotEngine, Request, ServeConfig, ServeEngine,
                         ft_logits, quantize_head)
from repro.train.straggler import DeadlineExecutor

rng = np.random.default_rng(0)


PROMPTS = [rng.integers(0, 256, 8).astype(np.int32) for _ in range(8)]


def _serve_wave(eng, failed_group=None):
    for r, p in enumerate(PROMPTS):
        eng.submit(Request(rid=r, prompt=p.copy(), max_new=8))
    if failed_group is None:
        done = eng.run_to_completion()
    else:
        done = eng.run_to_completion(failed_group=failed_group)
    return {r.rid: np.asarray(r.out) for r in done}


def main():
    cfg = get_smoke_config("llama3.2-1b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg, max_seq=128)

    # --- 1) batched vs per-slot serving ------------------------------------
    scfg = ServeConfig(max_batch=4, max_seq=128)
    t0 = time.monotonic()
    ref = _serve_wave(PerSlotEngine(cfg, scfg, params))
    t_ref = time.monotonic() - t0
    eng = ServeEngine(cfg, scfg, params)
    t0 = time.monotonic()
    out = _serve_wave(eng)
    t_bat = time.monotonic() - t0
    assert all(np.array_equal(ref[r], out[r]) for r in ref)
    print(f"[serve_lm] 8 requests: per-slot {t_ref:.2f}s vs batched "
          f"{t_bat:.2f}s ({eng.decode_calls} decode calls); outputs "
          f"bit-identical; sample: {list(out[0][:6])}")

    # --- 2) entangled head on the hot path: fail-stop roll-forward ---------
    ft_cfg = ServeConfig(max_batch=4, max_seq=128, ft_mode="entangle", ft_M=4)
    healthy = _serve_wave(ServeEngine(cfg, ft_cfg, params))
    for fg in range(4):
        injected = _serve_wave(ServeEngine(cfg, ft_cfg, params),
                               failed_group=fg)
        assert all(np.array_equal(healthy[r], injected[r]) for r in healthy)
    print("[serve_lm] entangled int8 head on every decode step: tokens "
          "bit-identical under a fail-stop in any of the 4 request groups")

    # --- 3) the standalone fused projection (library form) -----------------
    B, D = 8, cfg.d_model
    h = jnp.asarray(rng.normal(size=(B, D)).astype(np.float32))
    head = jnp.asarray(rng.normal(size=(D, cfg.vocab_size)).astype(np.float32) * 0.02)
    hq, ws = quantize_head(head)
    base = ft_logits(h, hq, ws, M=4)
    for fg in range(4):
        assert np.array_equal(np.asarray(ft_logits(h, hq, ws, M=4,
                                                   failed_group=fg)),
                              np.asarray(base))
    agree = float(jnp.mean((jnp.argmax(base, -1) ==
                            jnp.argmax(h @ head, -1)).astype(jnp.float32)))
    print(f"[serve_lm] standalone ft_logits: exact under any single-group "
          f"fail-stop; argmax agreement with f32 head: {agree:.2f}")

    # --- 4) straggler-as-fail-stop drill ------------------------------------
    def group_work(delay):
        def fn():
            time.sleep(delay)
            return "logits"
        return fn

    ex = DeadlineExecutor(deadline_s=0.25)
    results = ex.run([group_work(0.01), group_work(0.02),
                      group_work(5.0), group_work(0.015)])  # group 2 hangs
    failed = DeadlineExecutor.failed_index(results)
    print(f"[serve_lm] deadline drill: group {failed} missed the deadline -> "
          f"rolled forward via the entangled head (as in 2); no request "
          f"waited for the straggler")
    assert failed == 2


if __name__ == "__main__":
    main()
